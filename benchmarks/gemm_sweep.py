"""Paper Figs. 4-7 reproduction: S/D/C/Z x NN/NT/TN/TT small-GEMM sweep.

On this CPU container we cannot measure Kunpeng/TPU wall time, so the
sweep reports, per (dtype, transposition, size):

* modeled speedup of IAAT vs the traditional pipeline (roofline traffic
  model: pack bytes + fixed-kernel memops vs plan memops) — reproduces
  the paper's curve shape: large gains at small sizes decaying toward 1,
  with TN lower than the rest;
* interpret-mode CORRECTNESS of the planned kernel path vs the jnp
  oracle at selected sizes (the execution itself is validated in tests/);
* run-time-stage planning latency (IAAT's "runtime tuning" overhead,
  amortised by the plan cache).
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.core import cost, dispatch, paper_table, plan as plan_mod
from repro.core.tiler import tile_armv8
from repro.kernels import ref

_DT = {"S": jnp.float32, "D": jnp.float64, "C": jnp.complex64,
       "Z": jnp.complex128}


def modeled_speedup(letter: str, trans: str, n: int) -> float:
    """traditional time / IAAT time under the traffic model (per-element
    f32-equivalent traffic; compute equal for both sides)."""
    item = jnp.dtype(_DT[letter]).itemsize
    cx = letter in ("C", "Z")
    flops = cost.gemm_flops(n, n, n, cx)
    t = tile_armv8(n, n, letter, trans, "dp")
    iaat_traffic = t.memops(n) * item
    from benchmarks.tiling_memops import traditional_coeff
    trad_traffic = (traditional_coeff(n, n) * n + 2 * n * n) * item \
        + dispatch.traditional_pack_bytes(n, n, n, _DT[letter])
    peak = cost.PEAK_FLOPS_F32 / (2 if letter in ("D", "Z") else 1)
    t_iaat = max(flops / peak, iaat_traffic / cost.VMEM_BW)
    t_trad = max(flops / peak, trad_traffic / cost.VMEM_BW)
    return t_trad / t_iaat


def run(csv_rows) -> None:
    for letter in ("S", "D", "C", "Z"):
        for trans in ("NN", "NT", "TN", "TT"):
            limit = (paper_table.PAPER_SMALL_THRESHOLD_TN if trans == "TN"
                     else paper_table.PAPER_SMALL_THRESHOLD)
            sp = [modeled_speedup(letter, trans, n)
                  for n in range(2, limit + 1, 2)]
            csv_rows.append(
                (f"gemm_sweep/{letter}GEMM_{trans}_model_speedup_avg",
                 0.0, round(float(np.mean(sp)), 3)))
            csv_rows.append(
                (f"gemm_sweep/{letter}GEMM_{trans}_model_speedup_at8",
                 0.0, round(modeled_speedup(letter, trans, 8), 3)))
    # planning latency: cold vs cached (the run-time stage's own cost)
    plan_mod.build_plan.cache_clear()
    t0 = time.perf_counter()
    plan_mod.build_plan(300, 300, 300, "S", "NN", "dp")
    cold = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(1000):
        plan_mod.build_plan(300, 300, 300, "S", "NN", "dp")
    warm = (time.perf_counter() - t0)
    csv_rows.append(("gemm_sweep/plan_cold_us", round(cold, 1), 1))
    csv_rows.append(("gemm_sweep/plan_cached_us", round(warm, 3), 1000))
    # correctness spot-check through the full routed path
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(45, 33), jnp.float32)
    b = jnp.asarray(rng.randn(33, 77), jnp.float32)
    with api.using(backend="pallas", interpret=True):
        out = api.gemm(a, b)
    err = float(jnp.abs(out - ref.ref_gemm(a, b)).max())
    csv_rows.append(("gemm_sweep/dispatch_45x77x33_maxerr", 0.0, err))
    assert err < 1e-4
