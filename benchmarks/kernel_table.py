"""Paper TABLE I reproduction: the install-time generated-kernel census.

Reports the verbatim ARMv8 table counts (786 kernels across S/D/C/Z x
NN/NT/TN/TT) and our TPU/VMEM-derived table, asserting every generated
signature's footprint fits the VMEM budget and honours (sublane, lane)
alignment.
"""
from __future__ import annotations

import time

from repro.core import kernelgen, paper_table, vmem


def run(csv_rows) -> None:
    arm = paper_table.census()
    csv_rows.append(("kernel_table/armv8_total", 0.0,
                     paper_table.total_kernels()))
    for fam in ("SGEMM_NN", "SGEMM_TN", "ZGEMM_TT"):
        csv_rows.append((f"kernel_table/armv8_{fam}", 0.0, arm[fam]))
    tpu = kernelgen.census()
    csv_rows.append(("kernel_table/tpu_total", 0.0, sum(tpu.values())))
    for fam, n in tpu.items():
        csv_rows.append((f"kernel_table/tpu_{fam}", 0.0, n))
    # validity: every table entry fits VMEM and is grain-aligned
    for sig in kernelgen.full_table():
        fp = sig.footprint()
        assert fp.fits, sig
        assert sig.bm % vmem.sublane(sig.real_dtype) == 0, sig
        assert sig.bn % vmem.LANE == 0, sig
    # install-time build timing (a real cost the paper pays at install)
    t0 = time.perf_counter()
    n = kernelgen.install(letters=("S",), trans=("NN",), interpret=True)
    dt = (time.perf_counter() - t0) / max(n, 1) * 1e6
    csv_rows.append(("kernel_table/install_us_per_kernel", round(dt, 1), n))
