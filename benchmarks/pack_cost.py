"""Paper Fig. 3 reproduction: proportion of runtime spent in the pack step.

Two measurements:
* the roofline cost model (`core.cost.pack_cost_model`) over the paper's
  size range — reproduces the 67% -> ~3% exponential decay shape;
* measured wall time of the actual pack path vs the IAAT (pack-free) path
  on CPU via numpy (real copies, real GEMM) — a hardware-honest proxy for
  the paper's Kunpeng measurements.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import cost, dispatch


def measured_pack_fraction(M, N, K, iters=20) -> float:
    rng = np.random.RandomState(0)
    a = rng.randn(M, K).astype(np.float32)
    b = rng.randn(K, N).astype(np.float32)
    bm, bn, bk = 128, 256, 256
    Mp, Np, Kp = (-(M // -bm)) * bm, (-(N // -bn)) * bn, (-(K // -bk)) * bk
    t_pack = t_gemm = 0.0
    for _ in range(iters):
        t0 = time.perf_counter()
        ap = np.zeros((Mp, Kp), np.float32)
        bp = np.zeros((Kp, Np), np.float32)
        ap[:M, :K] = a              # the pack copies
        bp[:K, :N] = b
        t1 = time.perf_counter()
        ap @ bp
        t2 = time.perf_counter()
        t_pack += t1 - t0
        t_gemm += t2 - t1
    return t_pack / (t_pack + t_gemm)


def model_frac(n: int) -> float:
    """Pack fraction with REAL pack semantics: the packed buffers are
    padded to kernel multiples (that padding is exactly why packing hurts
    small GEMM), GEMM time = max(compute, traffic) roofline."""
    import jax.numpy as jnp
    from repro.core import dispatch
    pack_bytes = dispatch.traditional_pack_bytes(n, n, n, jnp.float32)
    t_pack = pack_bytes / cost.HBM_BW
    r = cost.gemm_roofline(n, n, n, 4, peak=cost.PEAK_FLOPS_F32)
    t_gemm = max(r.compute_s, r.memory_s)
    return t_pack / (t_pack + t_gemm)


def run(csv_rows) -> None:
    # paper Fig. 3 shape: 67% at tiny sizes decaying toward ~3%.  On TPU
    # the compute/bandwidth ratio is ~12x Kunpeng's, so the decay reaches
    # 3% only at n~32k — a hardware-adaptation observation recorded in
    # EXPERIMENTS.md, not a deviation from the paper's mechanism.
    for s in (4, 8, 16, 32, 64, 80, 256, 1024, 4096, 32768):
        csv_rows.append((f"pack_cost/model_frac_n{s}", 0.0,
                         round(model_frac(s), 4)))
    for s in (8, 16, 32, 64, 80, 256):
        f = measured_pack_fraction(s, s, s)
        csv_rows.append((f"pack_cost/measured_frac_n{s}", 0.0, round(f, 4)))
    small = model_frac(8)
    large = model_frac(32768)
    assert small > 0.6 and large < 0.1, (small, large)
