"""Roofline table from the dry-run artifacts (deliverable g).

Reads results/dryrun_baseline.json (written by repro.launch.dryrun) and
emits the per-(arch x shape) three-term roofline table as CSV rows and a
markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

_DEFAULT = ("results/dryrun_final.json"
            if os.path.exists("results/dryrun_final.json")
            else "results/dryrun_baseline.json")
RESULTS = os.environ.get("DRYRUN_JSON", _DEFAULT)


def markdown_table(results: dict, mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful_flops | mem/dev GiB | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for key in sorted(results):
        r = results[key]
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        rl = r["roofline"]
        mem = r["memory_analysis"].get("total_nonalias", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']:.3f} | "
            f"{mem:.2f} | {rl['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def run(csv_rows) -> None:
    if not os.path.exists(RESULTS):
        csv_rows.append(("roofline/missing_dryrun_json", 0.0, 0))
        return
    with open(RESULTS) as f:
        results = json.load(f)
    ok = [r for r in results.values() if r["status"] == "ok"]
    skipped = [r for r in results.values() if r["status"] == "skipped"]
    err = [r for r in results.values() if r["status"] == "error"]
    csv_rows.append(("roofline/cells_ok", 0.0, len(ok)))
    csv_rows.append(("roofline/cells_skipped", 0.0, len(skipped)))
    csv_rows.append(("roofline/cells_error", 0.0, len(err)))
    for r in ok:
        if r["mesh"] != "single":
            continue
        rl = r["roofline"]
        csv_rows.append((f"roofline/{r['arch']}/{r['shape']}/dominant={rl['dominant']}",
                         0.0, round(rl["roofline_fraction"], 5)))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_table.md", "w") as f:
        f.write("## Single-pod (16x16) roofline\n\n")
        f.write(markdown_table(results, "single"))
        f.write("\n\n## Multi-pod (2x16x16) roofline\n\n")
        f.write(markdown_table(results, "multi"))
        f.write("\n")
