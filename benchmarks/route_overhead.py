"""Router observability overhead micro-benchmark (the <5% gate).

Times ``Router.route`` over a fixed shape mix with the obs shape log on
vs off (``obs.set_enabled``).  The log entry doubles as a decision memo
— route is pure in (op, dims, dtype, trans, policy identity, profile
generation) — so the enabled path is expected to be *faster* on repeat
shapes, not just within 5%.  The acceptance row reports the relative
overhead; ``run()`` asserts the gate.

A second comparison (``measure_trace``) prices the flight recorder:
the same memo-hit loop with ``obs.TRACE`` on vs off, gated at the same
<5% — on hits the trace ring is never touched, so this is a regression
tripwire for anyone adding an emit to the hot path.
"""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

#: serving-like shape mix: a handful of distinct decode/prefill GEMMs
#: hit over and over (the memo's best case, and the realistic one — the
#: paper's premise is repeated same-size small GEMMs).
SHAPES = [(4, 512, 512), (4, 2048, 512), (16, 512, 512),
          (45, 77, 33), (128, 128, 128), (300, 300, 300)]


def _time_route(router, reps: int) -> float:
    """Seconds for ``reps`` passes over the shape mix."""
    t0 = time.perf_counter()
    for _ in range(reps):
        for dims in SHAPES:
            router.route("gemm", dims, "S", "NN")
    return time.perf_counter() - t0


def measure(reps: int = 2000):
    """Returns (enabled_us, disabled_us, overhead_fraction) per call."""
    from repro import api, obs

    router = api.Router(api.Policy(backend="auto"))
    ncalls = reps * len(SHAPES)
    was = obs.enabled()
    try:
        obs.set_enabled(True)
        obs.ROUTES.reset()
        _time_route(router, 50)                       # warm the memo
        t_on = _time_route(router, reps) / ncalls
        obs.set_enabled(False)
        _time_route(router, 50)
        t_off = _time_route(router, reps) / ncalls
    finally:
        obs.set_enabled(was)
    return t_on * 1e6, t_off * 1e6, (t_on - t_off) / t_off


def measure_trace(reps: int = 2000, retries: int = 2):
    """Returns (traced_us, untraced_us, overhead_fraction) per call for
    the memo-hit routing path with the flight recorder on vs off.

    Obs metrics stay ON both sides — this isolates what *tracing* adds,
    which on memo hits should be nothing at all: ``ROUTE_MISS`` only
    fires on the miss path, so the hot repeat-shape loop never touches
    the ring.  Sub-microsecond timings are noisy, so each side keeps its
    best over up to ``1 + retries`` rounds before the number is final.
    """
    from repro import api, obs

    router = api.Router(api.Policy(backend="auto"))
    ncalls = reps * len(SHAPES)
    was_obs, was_trace = obs.enabled(), obs.TRACE.on
    best_on = best_off = float("inf")
    try:
        obs.set_enabled(True)
        obs.ROUTES.reset()
        _time_route(router, 50)                       # warm the memo
        for _ in range(1 + retries):
            obs.TRACE.set_enabled(True)
            best_on = min(best_on, _time_route(router, reps) / ncalls)
            obs.TRACE.set_enabled(False)
            best_off = min(best_off, _time_route(router, reps) / ncalls)
            if best_on <= best_off * 1.05:
                break
    finally:
        obs.set_enabled(was_obs)
        obs.TRACE.set_enabled(was_trace)
    return best_on * 1e6, best_off * 1e6, (best_on - best_off) / best_off


def run(csv_rows) -> None:
    on_us, off_us, over = measure()
    csv_rows.append(("route_overhead/enabled_us", round(on_us, 3), 1))
    csv_rows.append(("route_overhead/disabled_us", round(off_us, 3), 1))
    csv_rows.append(("route_overhead/overhead_pct", round(over * 100, 1),
                     "gate<5"))
    assert over < 0.05, f"route() obs overhead {over:.1%} >= 5%"
    t_on_us, t_off_us, t_over = measure_trace()
    csv_rows.append(("route_overhead/traced_us", round(t_on_us, 3), 1))
    csv_rows.append(("route_overhead/trace_overhead_pct",
                     round(t_over * 100, 1), "gate<5"))
    assert t_over < 0.05, f"route() trace overhead {t_over:.1%} >= 5%"


def main() -> None:
    on_us, off_us, over = measure()
    print(f"route() with obs on:  {on_us:.3f} us/call")
    print(f"route() with obs off: {off_us:.3f} us/call")
    print(f"overhead: {over:+.1%} (gate: <5%)")
    t_on_us, t_off_us, t_over = measure_trace()
    print(f"route() with trace on:  {t_on_us:.3f} us/call")
    print(f"route() with trace off: {t_off_us:.3f} us/call")
    print(f"trace overhead: {t_over:+.1%} (gate: <5%)")


if __name__ == "__main__":
    main()
