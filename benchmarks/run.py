# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (gemm_sweep, kernel_table, pack_cost, roofline,
                            tiling_memops)
    suites = [
        ("tiling_memops", tiling_memops.run),   # paper Fig. 2
        ("pack_cost", pack_cost.run),           # paper Fig. 3
        ("kernel_table", kernel_table.run),     # paper TABLE I
        ("gemm_sweep", gemm_sweep.run),         # paper Figs. 4-7
        ("roofline", roofline.run),             # framework deliverable (g)
    ]
    rows = []
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            fn(rows)
            rows.append((f"{name}/suite_s", (time.perf_counter() - t0) * 1e6,
                         "ok"))
        except Exception as e:  # noqa: BLE001 — report and continue
            rows.append((f"{name}/suite_s", (time.perf_counter() - t0) * 1e6,
                         f"ERROR:{type(e).__name__}:{e}"))
    print("name,us_per_call,derived")
    bad = 0
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
        if isinstance(derived, str) and derived.startswith("ERROR"):
            bad += 1
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
