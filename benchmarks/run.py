# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# Exits nonzero when any suite reports an ERROR row (CI regression gate).
# ``--record`` additionally appends the serving headline numbers to the
# BENCH_serve.json trajectory (the per-PR perf history).
from __future__ import annotations

import os
import sys
import time

# runnable as `python benchmarks/run.py` from anywhere: put the repo root
# (for `benchmarks.*`) and src/ (for `repro.*`, when not pip-installed)
# on the path ourselves.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    from benchmarks import (gemm_sweep, kernel_table, pack_cost, roofline,
                            route_overhead, serve_stream, tiling_memops,
                            tune_report)
    record = "--record" in sys.argv[1:]
    suites = [
        ("tiling_memops", tiling_memops.run),   # paper Fig. 2
        ("pack_cost", pack_cost.run),           # paper Fig. 3
        ("kernel_table", kernel_table.run),     # paper TABLE I
        ("gemm_sweep", gemm_sweep.run),         # paper Figs. 4-7
        ("roofline", roofline.run),             # framework deliverable (g)
        ("tune_report", tune_report.run),       # empirical vs analytical
        ("route_overhead", route_overhead.run),  # obs <5% gate
        # Poisson serving stream, both engines; --record appends the
        # per-PR trajectory row
        ("serve_stream",
         lambda rows: serve_stream.run(rows, record=record)),
    ]
    if "--quick" in sys.argv[1:]:
        quick = {"tiling_memops", "kernel_table", "roofline", "tune_report",
                 "route_overhead"}
        suites = [s for s in suites if s[0] in quick]
    rows = []
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            fn(rows)
            rows.append((f"{name}/suite_s", (time.perf_counter() - t0) * 1e6,
                         "ok"))
        except Exception as e:  # noqa: BLE001 — report and continue
            rows.append((f"{name}/suite_s", (time.perf_counter() - t0) * 1e6,
                         f"ERROR:{type(e).__name__}:{e}"))
    print("name,us_per_call,derived")
    bad = 0
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
        if isinstance(derived, str) and derived.startswith("ERROR"):
            bad += 1
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
