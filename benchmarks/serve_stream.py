"""Streaming serving benchmark: Poisson arrivals against the wave-based
continuous batcher (the paper's decode-time small-GEMM regime under a
realistic open-loop load).

Requests arrive by a seeded exponential inter-arrival process and are
submitted to :class:`repro.serve.engine.ContinuousBatcher` at their
arrival times; the engine's own :mod:`repro.obs` instrumentation then
prices everything we report — time-to-first-token, end-to-end latency
(p50/p99), decode throughput, and wave occupancy.  ``main()`` exports
the numbers as ``BENCH_serve.json`` (the repo's first checked-in
observability baseline); ``run()`` folds the headline rows into the
``benchmarks/run.py`` CSV.

    PYTHONPATH=src python benchmarks/serve_stream.py --requests 16
"""
from __future__ import annotations

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def stream(n_requests: int = 16, rate_hz: float = 4.0, *, slots: int = 4,
           max_new: int = 8, prompt_lo: int = 4, prompt_hi: int = 16,
           model_name: str = "glm4-9b", policy: str = "xla",
           seed: int = 0):
    """Run the open-loop stream; returns (meta, wall_s, tokens).

    Arrival times are drawn up front (seeded, reproducible); the loop
    submits every request whose arrival time has passed, runs one wave,
    and otherwise sleeps until the next arrival — so admission wait
    honestly includes the wave the scheduler was busy with.
    """
    import jax
    import numpy as np

    from repro import api, configs, obs
    from repro.models.registry import build
    from repro.serve.engine import ContinuousBatcher, Request

    cfg = configs.get_smoke(model_name)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    api.install(api.named_policy(policy))
    batcher = ContinuousBatcher(model, params, slots=slots, max_len=128,
                                temperature=0.8, seed=seed)

    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    prompts = [rng.randint(0, cfg.vocab,
                           rng.randint(prompt_lo, prompt_hi)).astype(np.int32)
               for _ in range(n_requests)]
    arrivals = np.cumsum(gaps)

    # warm the jit caches off the clock: one throwaway wave end-to-end.
    batcher.submit(Request(-1, prompts[0], max_new=2))
    batcher.run()
    obs.reset()

    t0 = time.perf_counter()
    nxt = 0
    while len(batcher.done) < n_requests:
        now = time.perf_counter() - t0
        while nxt < n_requests and arrivals[nxt] <= now:
            batcher.submit(Request(nxt, prompts[nxt], max_new=max_new))
            nxt += 1
        if not batcher.step() and nxt < n_requests:
            time.sleep(max(0.0, arrivals[nxt] - (time.perf_counter() - t0)))
    wall = time.perf_counter() - t0
    tokens = sum(len(v) for v in batcher.done.values())
    meta = {
        "model": model_name, "policy": policy, "slots": slots,
        "requests": n_requests, "rate_hz": rate_hz, "max_new": max_new,
        "seed": seed, "wall_s": round(wall, 3), "tokens": tokens,
        "tokens_per_s": round(tokens / wall, 2),
    }
    return meta, wall, tokens


def _headline(meta):
    from repro import obs
    e2e = obs.REGISTRY.get("serve.e2e_us")
    ttft = obs.REGISTRY.get("serve.ttft_us")
    rows = [("serve_stream/tokens_per_s", meta["tokens_per_s"],
             meta["tokens"])]
    if e2e is not None and e2e.n:
        rows += [("serve_stream/e2e_p50_us", round(e2e.p50, 1), e2e.n),
                 ("serve_stream/e2e_p99_us", round(e2e.p99, 1), e2e.n)]
    if ttft is not None and ttft.n:
        rows += [("serve_stream/ttft_p50_us", round(ttft.p50, 1), ttft.n)]
    return rows


def run(csv_rows) -> None:
    """benchmarks/run.py entry: a small stream, headline rows only."""
    meta, _, _ = stream(n_requests=8, rate_hz=4.0, max_new=4)
    csv_rows.extend(_headline(meta))


def main() -> None:
    from repro import obs
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate-hz", type=float, default=4.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--model", default="glm4-9b")
    ap.add_argument("--policy", default="xla",
                    choices=("xla", "pallas", "auto", "tuned"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-export", action="store_true",
                    help="print the report without writing BENCH_serve.json")
    args = ap.parse_args()
    meta, wall, tokens = stream(
        args.requests, args.rate_hz, slots=args.slots, max_new=args.max_new,
        model_name=args.model, policy=args.policy, seed=args.seed)
    for name, val, n in _headline(meta):
        print(f"{name}: {val}  (n={n})")
    print(f"{meta['requests']} requests in {wall:.2f}s "
          f"-> {meta['tokens_per_s']} tok/s")
    if not args.no_export:
        path = obs.export_bench("serve", meta)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
