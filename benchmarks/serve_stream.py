"""Streaming serving benchmark: Poisson arrivals against both serving
engines (the paper's decode-time small-GEMM regime under a realistic
open-loop load).

Requests arrive by a seeded exponential inter-arrival process and are
submitted at their arrival times to either the paged slot-level engine
(:class:`repro.serve.PagedEngine`, the default) or the wave-based
reference (:class:`repro.serve.ContinuousBatcher`); the engines' own
:mod:`repro.obs` instrumentation then prices everything we report —
time-to-first-token, end-to-end latency (p50/p99), admission wait,
decode throughput, slot/wave occupancy.  ``main()`` exports the numbers
as ``BENCH_serve.json`` with a per-engine summary in ``meta`` so one
file records the paged-vs-wave comparison; ``--model`` repeats to
stream several smoke archs (per-model sections land under
``meta.models`` — this is how the recurrent families get their own
paged rows); ``--gate`` fails the run when any streamed model's paged
p99 end-to-end latency regresses >20% against the checked-in baseline,
and ``--record`` appends a trajectory row (the per-PR history
``benchmarks/run.py --record`` maintains).  ``--trace PATH``
additionally dumps the last paged stream's flight-recorder timeline as
a Chrome-trace/Perfetto JSON (slots as tracks, requests as
flow-connected slices) and the per-request reducer's distributions
(queue wait, TTFT wait-vs-prefill split, decode stall) always land in
the export as ``serve.trace.*``; ``--trace-gate`` fails the run when
tracing costs more than 5% paged tokens/s.  ``--online-tune`` streams
the primary model once more with the background traffic-aware re-tuner
running (``--online-profile PATH`` saves the resulting profile — the
CI artifact) and ``--online-gate`` fails the run when the tuner costs
more than 5% paged tokens/s (same best-of-retries shape as the trace
gate).

    PYTHONPATH=src python benchmarks/serve_stream.py --requests 16
    PYTHONPATH=src python benchmarks/serve_stream.py --engine both --gate
    PYTHONPATH=src python benchmarks/serve_stream.py \
        --model glm4-9b --model mamba2-780m --engine both --record
"""
from __future__ import annotations

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

GATE_PCT = 20.0     # p99 e2e regression tolerance vs checked-in baseline
TRACE_GATE_PCT = 5.0    # tokens/s loss tolerance with the flight recorder on
ONLINE_GATE_PCT = 5.0   # tokens/s loss tolerance with the online tuner on


def _build_engine(engine, model, params, *, slots, seed):
    from repro.serve import ContinuousBatcher, PagedEngine
    if engine == "paged":
        return PagedEngine(model, params, slots=slots, max_len=128,
                           temperature=0.8, seed=seed, block_size=16,
                           chunk=16)
    return ContinuousBatcher(model, params, slots=slots, max_len=128,
                             temperature=0.8, seed=seed)


def stream(n_requests: int = 16, rate_hz: float = 4.0, *, slots: int = 4,
           max_new: int = 8, prompt_lo: int = 4, prompt_hi: int = 16,
           model_name: str = "glm4-9b", policy: str = "xla",
           seed: int = 0, engine: str = "paged", online: bool = False,
           online_profile=None, online_tuner=None):
    """Run the open-loop stream; returns (meta, wall_s, tokens).

    Arrival times are drawn up front (seeded, reproducible); the loop
    submits every request whose arrival time has passed, runs one engine
    step (a wave for the reference engine, one decode iteration for the
    paged engine), and otherwise sleeps until the next arrival — so
    admission wait honestly includes whatever the scheduler was busy
    with.  The same seed drives both engines, so a ``--engine both``
    comparison sees an identical arrival process and workload.

    ``online=True`` (paged only) runs a background
    :class:`repro.tune.online.OnlineTuner` for the stream's duration —
    the `--online-tune` smoke and the `--online-gate` overhead
    comparison.  ``online_tuner`` injects a caller-owned tuner (the
    gate reuses one across attempts so its done-tracking converges to
    the sweep-free steady state); otherwise a fresh small-budget one is
    built.  ``online_profile`` saves whatever profile the tuner left
    active to that path (the CI artifact); the active profile is
    cleared afterwards either way so later streams start clean.
    """
    import jax
    import numpy as np

    from repro import api, configs, obs
    from repro.serve import Request

    cfg = configs.get_smoke(model_name)
    from repro.models.registry import build
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    api.install(api.named_policy(policy))
    srv = _build_engine(engine, model, params, slots=slots, seed=seed)

    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    prompts = [rng.randint(0, cfg.vocab,
                           rng.randint(prompt_lo, prompt_hi)).astype(np.int32)
               for _ in range(n_requests)]
    arrivals = np.cumsum(gaps)

    # warm the jit caches off the clock: one throwaway request end-to-end
    # (dropped from ``done`` so the stream serves all n_requests and the
    # token/latency counts don't include it).
    srv.submit(Request(-1, prompts[0], max_new=2))
    srv.run()
    srv.done.clear()
    online = online and engine == "paged"
    if online:
        # routing happens at jit TRACE time, so the warmup's route()
        # calls ARE the observed traffic the tuner's windowed feed sees
        # (the compiled steps never re-route); keep ROUTES, reset the
        # rest so latency numbers still exclude the warmup
        obs.REGISTRY.reset()
        obs.TRACE.reset()
    else:
        obs.reset()

    tuner = None
    if online:
        tuner = online_tuner
        if tuner is None:
            from repro.tune.online import OnlineTuner
            tuner = OnlineTuner(interval_s=0.3, budget=4, top=1, reps=1,
                                max_dim=512)
        tuner.start()
    t0 = time.perf_counter()
    try:
        nxt = 0
        while len(srv.done) < n_requests:
            now = time.perf_counter() - t0
            while nxt < n_requests and arrivals[nxt] <= now:
                srv.submit(Request(nxt, prompts[nxt], max_new=max_new))
                nxt += 1
            if not srv.step() and nxt < n_requests:
                time.sleep(max(0.0,
                               arrivals[nxt] - (time.perf_counter() - t0)))
    finally:
        if tuner is not None:
            tuner.stop()
    wall = time.perf_counter() - t0
    tokens = sum(len(v) for v in srv.done.values())
    meta = {
        "engine": engine, "model": model_name, "policy": policy,
        "slots": slots, "requests": n_requests, "rate_hz": rate_hz,
        "max_new": max_new, "seed": seed, "wall_s": round(wall, 3),
        "tokens": tokens, "tokens_per_s": round(tokens / wall, 2),
    }
    if tuner is not None:
        from repro.tune import profile as profile_mod
        meta["online"] = {"cycles": tuner.cycles, "swaps": tuner.swaps}
        if online_profile is not None:
            prof = profile_mod.active_profile()
            if prof is None:        # no swap landed: still emit a valid doc
                prof = profile_mod.DeviceProfile(
                    profile_mod.current_device_kind())
            meta["online"]["profile"] = str(prof.save(online_profile))
            meta["online"]["entries"] = len(prof)
        profile_mod.clear_active_profile()
    return meta, wall, tokens


def _summary(meta):
    """Fold the live registry into one comparable per-engine dict."""
    from repro import obs
    out = {"tokens_per_s": meta["tokens_per_s"], "tokens": meta["tokens"],
           "wall_s": meta["wall_s"]}
    for short, metric in (("ttft", "serve.ttft_us"),
                          ("e2e", "serve.e2e_us"),
                          ("admission_wait", "serve.admission_wait_us")):
        h = obs.REGISTRY.get(metric)
        if h is not None and h.n:
            out[f"{short}_p50_us"] = round(h.p50, 1)
            out[f"{short}_p99_us"] = round(h.p99, 1)
    pre = obs.REGISTRY.get("serve.preemptions")
    if pre is not None:
        out["preemptions"] = pre.value
    return out


def _headline(meta, prefix="serve_stream"):
    from repro import obs
    e2e = obs.REGISTRY.get("serve.e2e_us")
    ttft = obs.REGISTRY.get("serve.ttft_us")
    rows = [(f"{prefix}/tokens_per_s", meta["tokens_per_s"],
             meta["tokens"])]
    if e2e is not None and e2e.n:
        rows += [(f"{prefix}/e2e_p50_us", round(e2e.p50, 1), e2e.n),
                 (f"{prefix}/e2e_p99_us", round(e2e.p99, 1), e2e.n)]
    if ttft is not None and ttft.n:
        rows += [(f"{prefix}/ttft_p50_us", round(ttft.p50, 1), ttft.n)]
    return rows


def bench(engines, **kw):
    """Run the stream per engine (fresh metrics each) and return
    ``(meta, rows)`` where ``meta['engines'][name]`` holds each engine's
    summary and the live registry holds the LAST engine's metrics (the
    snapshot ``export_bench`` writes — paged last, so the checked-in
    metrics block tracks the default engine)."""
    from repro import obs
    from repro.obs import trace as trace_mod
    meta, rows = {}, []
    for engine in engines:
        obs.reset()
        m, _, _ = stream(engine=engine, **kw)
        # fold the flight recorder's per-request reducer into the live
        # registry BEFORE _summary/export snapshot it, so the derived
        # serve.trace.* distributions (queue wait, TTFT wait-vs-prefill,
        # decode stall) land in BENCH_serve.json next to the engine's
        # own aggregates.  The wave engine doesn't emit trace events, so
        # its section simply carries no trace block.
        per = trace_mod.per_request(obs.TRACE.snapshot())
        if per:
            trace_mod.observe(per)
        summ = _summary(m)
        if per:
            summ["trace"] = trace_mod.summary(per)
        meta.setdefault("engines", {})[engine] = summ
        rows.extend(_headline(m, prefix=f"serve_stream[{engine}]"))
        meta.update({k: v for k, v in m.items()
                     if k not in ("engine", "wall_s", "tokens",
                                  "tokens_per_s")})
    return meta, rows


def baseline_p99(doc, model: str | None = None) -> float:
    """Paged p99 e2e from a BENCH_serve doc.  ``model`` reads that
    model's section under ``meta.models``; docs from before multi-model
    runs fall back to the top-level engines block (which priced the
    doc's primary model) and, older still, to the top-level metric
    (which then priced the wave engine)."""
    meta = doc.get("meta", {})
    if model is not None:
        sec = meta.get("models", {}).get(model, {}).get("engines", {})
        p99 = sec.get("paged", {}).get("e2e_p99_us")
        if p99:
            return float(p99)
        if meta.get("model") not in (None, model):
            return 0.0              # baseline never measured this model
    eng = meta.get("engines", {})
    p99 = eng.get("paged", {}).get("e2e_p99_us")
    if p99 is None:
        p99 = doc.get("metrics", {}).get("serve.e2e_us", {}).get("p99")
    return float(p99) if p99 else 0.0


def check_gate(baseline_doc, new_p99: float, model: str | None = None):
    """Returns (ok, message) for the p99-e2e regression gate."""
    tag = f"[{model}] " if model else ""
    old = baseline_p99(baseline_doc, model)
    if not old:
        return True, f"gate: {tag}no baseline p99 — skipped"
    pct = (new_p99 - old) / old * 100.0
    ok = pct <= GATE_PCT
    return ok, (f"gate: {tag}paged e2e p99 {new_p99:.0f}us vs baseline "
                f"{old:.0f}us ({pct:+.1f}%, limit +{GATE_PCT:.0f}%)")


def check_trace_gate(model_name: str = "glm4-9b", retries: int = 2, **kw):
    """Returns (ok, message) for the tracing-overhead gate: paged
    tokens/s with the flight recorder ON must be within
    ``TRACE_GATE_PCT`` of the same stream with it OFF.  A short smoke
    stream's throughput is noisy (one host hiccup skews either side), so
    each side keeps its best over up to ``1 + retries`` attempts and the
    comparison only fails when the traced side loses every time."""
    from repro import obs
    was = obs.TRACE.on
    best = {"on": 0.0, "off": 0.0}
    attempt = 0
    try:
        for attempt in range(1 + retries):
            for mode in ("off", "on"):
                obs.reset()
                obs.TRACE.set_enabled(mode == "on")
                m, _, _ = stream(engine="paged", model_name=model_name,
                                 **kw)
                best[mode] = max(best[mode], m["tokens_per_s"])
            if best["on"] >= best["off"] * (1 - TRACE_GATE_PCT / 100.0):
                break
    finally:
        obs.TRACE.set_enabled(was)
        obs.reset()
    if best["off"] <= 0:
        return True, "trace-gate: no untraced throughput — skipped"
    drop = (best["off"] - best["on"]) / best["off"] * 100.0
    ok = drop <= TRACE_GATE_PCT
    return ok, (f"trace-gate: paged {best['on']:.1f} tok/s traced vs "
                f"{best['off']:.1f} untraced ({drop:+.1f}% drop, limit "
                f"{TRACE_GATE_PCT:.0f}%) [attempts: {attempt + 1}]")


def check_online_gate(model_name: str = "glm4-9b", retries: int = 2, **kw):
    """Returns (ok, message) for the online-tuner overhead gate: paged
    tokens/s with the background re-tuner running must be within
    ``ONLINE_GATE_PCT`` of the same stream without it.

    The gate prices the tuner's *steady state*: one tuner is shared
    across attempts, and an untimed warm pass (a full tuner-on stream,
    then draining ``cycle()`` until nothing re-tunes) pays the one-off
    sweep of the hot classes — candidate compiles included — off the
    clock.  After convergence each cycle is a weigher pass that the
    done-tracker resolves to "no shift, nothing to time", which is what
    a long-lived deployment pays per interval; the cold sweep is a
    bounded one-off (``budget`` timings), not a per-stream tax, so
    gating it against a 2-second smoke stream would only measure the
    smallness of the stream.  Same best-of shape as the trace gate:
    each side keeps its best over up to ``1 + retries`` attempts and
    the comparison only fails when the tuner-on side loses every time
    (a short smoke stream's throughput is noisy; a real regression
    loses every repeat)."""
    from repro import obs
    from repro.tune.online import OnlineTuner
    tuner = OnlineTuner(interval_s=0.3, budget=4, top=1, reps=1,
                        max_dim=512)
    best = {"on": 0.0, "off": 0.0}
    attempt = 0
    try:
        obs.reset()
        stream(engine="paged", model_name=model_name, online=True,
               online_tuner=tuner, **kw)        # warm pass, untimed
        for _ in range(16):                     # drain remaining classes
            if not tuner.cycle().retuned:
                break
        for attempt in range(1 + retries):
            for mode in ("off", "on"):
                obs.reset()
                m, _, _ = stream(engine="paged", model_name=model_name,
                                 online=(mode == "on"),
                                 online_tuner=tuner if mode == "on"
                                 else None, **kw)
                best[mode] = max(best[mode], m["tokens_per_s"])
            if best["on"] >= best["off"] * (1 - ONLINE_GATE_PCT / 100.0):
                break
    finally:
        obs.reset()
    if best["off"] <= 0:
        return True, "online-gate: no tuner-off throughput — skipped"
    drop = (best["off"] - best["on"]) / best["off"] * 100.0
    ok = drop <= ONLINE_GATE_PCT
    return ok, (f"online-gate: paged {best['on']:.1f} tok/s tuner-on vs "
                f"{best['off']:.1f} tuner-off ({drop:+.1f}% drop, limit "
                f"{ONLINE_GATE_PCT:.0f}%) [attempts: {attempt + 1}]")


def run(csv_rows, record: bool = False) -> None:
    """benchmarks/run.py entry: a small stream per engine, headline rows
    only; ``--record`` additionally appends the per-PR trajectory row."""
    from repro import obs
    meta, rows = bench(("wave", "paged"), n_requests=8, rate_hz=4.0,
                       max_new=4)
    csv_rows.extend(rows)
    if record:
        obs.record_trajectory("serve", {"engines": meta["engines"],
                                        "requests": meta["requests"],
                                        "rate_hz": meta["rate_hz"]})


def main() -> None:
    import json
    import pathlib

    from repro import obs
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engine", default="both",
                    choices=("paged", "wave", "both"))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate-hz", type=float, default=4.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--model", action="append", default=None,
                    help="smoke arch to stream (repeatable; first one is "
                         "the primary whose engines block tops the "
                         "export; default glm4-9b)")
    ap.add_argument("--policy", default="xla",
                    choices=("xla", "pallas", "auto", "tuned"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gate", action="store_true",
                    help=f"fail when paged e2e p99 regresses more than "
                         f"{GATE_PCT:.0f}%% vs the checked-in "
                         f"BENCH_serve.json")
    ap.add_argument("--record", action="store_true",
                    help="append a per-PR trajectory row to "
                         "BENCH_serve.json")
    ap.add_argument("--no-export", action="store_true",
                    help="print the report without writing BENCH_serve.json")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the last paged stream's flight-recorder "
                         "timeline as Chrome-trace/Perfetto JSON")
    ap.add_argument("--trace-gate", action="store_true",
                    help=f"fail when tracing costs more than "
                         f"{TRACE_GATE_PCT:.0f}%% paged tokens/s")
    ap.add_argument("--online-tune", action="store_true",
                    help="additionally stream the primary model once "
                         "with the background re-tuner running (cycle/"
                         "swap counts land under meta.online)")
    ap.add_argument("--online-gate", action="store_true",
                    help=f"fail when the online tuner costs more than "
                         f"{ONLINE_GATE_PCT:.0f}%% paged tokens/s")
    ap.add_argument("--online-profile", metavar="PATH", default=None,
                    help="save the profile the --online-tune stream left "
                         "active (the CI artifact)")
    args = ap.parse_args()

    # snapshot the checked-in baseline BEFORE the export overwrites it
    bench_path = obs.bench_root() / "BENCH_serve.json"
    baseline = None
    if args.gate and bench_path.exists():
        baseline = json.loads(pathlib.Path(bench_path).read_text())

    engines = ("wave", "paged") if args.engine == "both" else (args.engine,)
    models = args.model or ["glm4-9b"]
    kw = dict(n_requests=args.requests, rate_hz=args.rate_hz,
              slots=args.slots, max_new=args.max_new, policy=args.policy,
              seed=args.seed)
    meta = None
    for i, mn in enumerate(models):
        m, rows = bench(engines, model_name=mn, **kw)
        if i == 0:
            # primary model keeps the legacy top-level engines block
            meta = m
            meta["models"] = {}
        meta["models"][mn] = {"engines": m["engines"]}
        for name, val, n in rows:
            suffix = f"@{mn}" if len(models) > 1 else ""
            print(f"{name}{suffix}: {val}  (n={n})")
        for engine, s in m["engines"].items():
            print(f"[{mn}:{engine}] {s['tokens']} tokens in {s['wall_s']}s "
                  f"-> {s['tokens_per_s']} tok/s")

    # the forced-xla default (iaat=False) never calls route(), so the
    # tuner's windowed feed would stay empty — online runs promote it
    # to "auto" (input-aware routing, identical on both gate sides so
    # the overhead comparison stays apples-to-apples)
    okw = dict(kw, policy="auto" if args.policy == "xla" else args.policy)

    if args.online_tune:
        obs.reset()
        m, _, _ = stream(engine="paged", model_name=models[0], online=True,
                         online_profile=args.online_profile, **okw)
        meta["online"] = m.get("online", {})
        print(f"[online-tune] {m['tokens_per_s']} tok/s; "
              f"cycles={meta['online'].get('cycles')} "
              f"swaps={meta['online'].get('swaps')}"
              + (f"; profile -> {meta['online']['profile']} "
                 f"({meta['online']['entries']} entries)"
                 if "profile" in meta["online"] else ""))

    if args.trace:
        # the live ring still holds the LAST stream run (the online one
        # when --online-tune — its TUNE_CYCLE/PROFILE_SWAP events land
        # in the timeline — else paged last when --engine both); dump it
        # before the gates re-run anything
        from repro.obs import trace as trace_mod
        tpath = trace_mod.write_trace(args.trace, slots=args.slots)
        print(f"trace: {tpath} ({len(trace_mod.TRACE)} events, "
              f"{trace_mod.TRACE.dropped} dropped; open in "
              f"https://ui.perfetto.dev)")

    if not args.no_export:
        path = obs.export_bench("serve", meta)
        print(f"wrote {path}")
    if args.record:
        obs.record_trajectory("serve", {"engines": meta["engines"],
                                        "models": meta["models"],
                                        "requests": args.requests,
                                        "rate_hz": args.rate_hz})
        print("appended trajectory row")

    failed = False
    if args.gate and "paged" in engines:
        for mn in models:
            sec = meta["models"][mn]["engines"]
            if "paged" not in sec:
                continue
            ok, msg = check_gate(baseline or {},
                                 sec["paged"].get("e2e_p99_us", 0.0), mn)
            # over a short open-loop stream p99 is nearly a max
            # statistic — one host hiccup doubles it — so re-measure
            # before failing; a real capability regression fails every
            # repeat.
            retries = 0
            while not ok and retries < 2:
                retries += 1
                obs.reset()
                m, _, _ = stream(engine="paged", model_name=mn, **kw)
                ok, msg = check_gate(baseline or {},
                                     _summary(m).get("e2e_p99_us", 0.0),
                                     mn)
            print(msg + (f" [retries: {retries}]" if retries else ""))
            failed = failed or not ok
    if args.trace_gate:
        ok, msg = check_trace_gate(model_name=models[0], **kw)
        print(msg)
        failed = failed or not ok
    if args.online_gate:
        ok, msg = check_online_gate(model_name=models[0], **okw)
        print(msg)
        failed = failed or not ok
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
