"""Paper Fig. 2 reproduction: tiling memops for small SGEMM_NN.

The paper reports that its input-aware tiling of a 15x15 SGEMM_NN loads
72K+450 elements vs 105K+450 for the traditional fixed-kernel tiling
(45% more).  We reproduce the 72 coefficient EXACTLY with the DP planner
over the verbatim ARMv8 TABLE I (12x{6,6,3} + 3x{13,2}), and report the
paper's Algorithm-2 greedy for comparison, plus a sweep over all small
sizes showing DP <= greedy everywhere (our beyond-paper improvement to
the run-time stage).
"""
from __future__ import annotations

from repro.core import paper_table
from repro.core.tiler import tile_armv8


def traditional_coeff(M: int, N: int) -> int:
    """Traditional tiling: fixed square kernels chosen greedily from
    {4,3,2,1} on BOTH dims, with no input-aware (m x n) co-selection —
    the key difference from IAAT is that n is never widened to 6/13.
    Gives 120 for 15x15 (paper's own traditional figure is 105; both are
    ~1.5-1.7x the IAAT 72 — the conclusion is unchanged)."""
    def split(L):
        out, rest = [], L
        for k in (4, 3, 2, 1):
            while rest >= k and (k > 1 or rest > 0):
                if rest - k in (1,) and k == 4 and rest != 4:
                    break
                out.append(k)
                rest -= k
                if k != 4:
                    break
        while rest:
            out.append(1)
            rest -= 1
        return out
    ms, ns = split(M), split(N)
    return sum(m + n for m in ms for n in ns)


def run(csv_rows) -> None:
    t_dp = tile_armv8(15, 15, "S", "NN", "dp")
    t_gr = tile_armv8(15, 15, "S", "NN", "greedy")
    trad = traditional_coeff(15, 15)
    csv_rows.append(("tiling_memops/15x15_dp_coeff", 0.0, t_dp.coeff))
    csv_rows.append(("tiling_memops/15x15_greedy_coeff", 0.0, t_gr.coeff))
    csv_rows.append(("tiling_memops/15x15_traditional_coeff", 0.0, trad))
    csv_rows.append(("tiling_memops/15x15_paper_iaat", 0.0,
                     paper_table.PAPER_FIG2_IAAT_COEFF))
    assert t_dp.coeff == paper_table.PAPER_FIG2_IAAT_COEFF, \
        f"DP coeff {t_dp.coeff} != paper 72"
    # sweep: DP vs greedy over all sizes the paper calls small
    wins = ties = total = 0
    worst = (0, 0, 0)
    for M in range(1, 33):
        for N in range(1, 33):
            dp = tile_armv8(M, N, "S", "NN", "dp").coeff
            gr = tile_armv8(M, N, "S", "NN", "greedy").coeff
            assert dp <= gr, (M, N, dp, gr)
            total += 1
            if dp < gr:
                wins += 1
                if gr - dp > worst[2]:
                    worst = (M, N, gr - dp)
            else:
                ties += 1
    csv_rows.append(("tiling_memops/dp_strictly_better_cells", 0.0, wins))
    csv_rows.append(("tiling_memops/dp_equal_cells", 0.0, ties))
    csv_rows.append((f"tiling_memops/max_gain_at_{worst[0]}x{worst[1]}",
                     0.0, worst[2]))
