"""Tuned vs analytical kernel selection — the empirical-stage deliverable.

Runs the quick cube sweep (S/NN, interpret mode) in memory and reports,
per size class:

* whether the measured backend choice agrees with the analytical
  crossover (``TPU_SCALE`` napkin math in DESIGN.md);
* the speedup of following the *measured* decision over the analytical
  one, from the sweep's own timings (1.0 when they agree).

In the CPU container interpret-mode pallas timings are pessimistic, so
disagreements here typically flip toward XLA; on a real TPU the same
report quantifies what the profile buys at each size.  Nothing below
touches the persistent cache — the sweep stays in memory.
"""
from __future__ import annotations


def run(csv_rows) -> None:
    from repro import api
    from repro.tune import classes as classes_mod, search

    prof = search.sweep(["S"], ["NN"], min_dim=8, max_dim=64,
                        cube_only=True, top=2, warmup=1, reps=2,
                        interpret=True, device_kind="bench")
    agree = 0
    for key, entry in sorted(prof.entries.items()):
        sc = classes_mod.SizeClass.from_key(key)
        M, N, K = classes_mod.representative(sc)
        analytical = api.route(
            "gemm", (M, N, K), sc.letter, sc.trans,
            policy=api.Policy(backend="auto")).use_pallas
        tuned = entry.prefer_pallas
        agree += analytical == tuned
        t_an = entry.pallas if analytical else entry.xla
        t_tu = entry.pallas if tuned else entry.xla
        tag = key.replace("/", "_")
        if t_an is None or t_tu is None:
            csv_rows.append((f"tune_report/{tag}_speedup", 0.0, "n/a"))
            continue
        csv_rows.append((f"tune_report/{tag}_speedup",
                         t_tu.median_us,
                         round(t_an.median_us / t_tu.median_us, 3)))
    csv_rows.append(("tune_report/agreement", 0.0,
                     f"{agree}of{len(prof)}"))
