"""MoE expert compute through IAAT batched small GEMMs — the paper's
"small GEMM in machine learning" scenario, at framework scale.

Shows: capacity routing, the (E, C, d) grouped layout, the unified
Policy + Router picking the grouped kernel and its blocks (the same
input-aware decision layer the 2-D path uses, profile-refined under
``backend="tuned"``), and the decode-time regime where per-expert token
counts are tiny (exactly the paper's target).

    PYTHONPATH=src python examples/moe_iaat.py
"""
import jax
import jax.numpy as jnp

from repro import api, configs
from repro.models import layers as L

cfg = configs.get_smoke("moonshot-v1-16b-a3b")
m = cfg.moe
key = jax.random.PRNGKey(0)
p = L.init_moe(key, cfg, jnp.float32)

XLA = api.named_policy("xla")
PALLAS = api.named_policy("pallas")

# --- prefill regime: many tokens per expert --------------------------------
x = jax.random.normal(key, (4, 64, cfg.d_model), jnp.float32) * 0.3
y_xla, aux = L.moe(p, x, XLA, cfg)
y_pl, _ = L.moe(p, x, PALLAS, cfg)
print(f"prefill: {x.shape[0] * x.shape[1]} tokens over {m.num_experts} "
      f"experts top-{m.top_k}; pallas-vs-xla maxerr "
      f"{float(jnp.abs(y_xla - y_pl).max()):.2e}, aux={float(aux):.4f}")

# --- decode regime: the paper's small-GEMM case ----------------------------
xd = jax.random.normal(key, (8, 1, cfg.d_model), jnp.float32) * 0.3
yd_xla, _ = L.moe(p, xd, XLA, cfg)
yd_pl, _ = L.moe(p, xd, PALLAS, cfg)
print(f"decode: 8 tokens -> per-expert GEMMs of ~"
      f"{8 * m.top_k // m.num_experts + 1} rows (cbrt(MNK)~"
      f"{(3 * cfg.d_model * m.d_expert) ** (1 / 3):.0f}): maxerr "
      f"{float(jnp.abs(yd_xla - yd_pl).max()):.2e}")

# --- the raw grouped op through the router ---------------------------------
E, C, K, N = m.num_experts, 16, cfg.d_model, m.d_expert
d = api.route("batched_gemm", (E, C, K, N), jnp.float32, policy=PALLAS)
print(f"route(batched_gemm, {E}x{C}x{K}x{N}) -> use_pallas={d.use_pallas} "
      f"source={d.source!r} blocks={d.blocks}")
tuned = api.route("batched_gemm", (E, C, K, N), jnp.float32,
                  policy=api.named_policy("tuned"))
print(f"  under backend='tuned' (no profile on disk it degrades): "
      f"source={tuned.source!r} blocks={tuned.blocks}")

xb = jax.random.normal(key, (E, C, K), jnp.float32)
wb = jax.random.normal(key, (E, K, N), jnp.float32)
out = api.batched_gemm(xb, wb, policy=PALLAS)
want = jnp.einsum("eck,ekn->ecn", xb, wb)
print(f"batched_gemm ({E} x {C}x{K}x{N}): maxerr "
      f"{float(jnp.abs(out - want).max()):.2e}")

# --- smallness criterion in action -----------------------------------------
for T in (2, 64, 4096):
    dec = api.route("gemm", (T, N, K), "S")
    print(f"  {T:5d} tokens x ({K}->{N}): IAAT path? {dec.use_pallas} "
          f"({dec.source})")
