"""Quickstart: the paper's technique end-to-end in 60 lines.

1. install-time stage: generate the kernel table
2. run-time stage: one Policy + Router routes the small GEMM
3. execute the kernel plan (Pallas interpret mode on CPU)
4. compare against the traditional (pack-step) pipeline

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import cost, dispatch, kernelgen, paper_table, plan
from repro.core.tiler import tile_armv8
from repro.kernels import ref

# -- 1. install-time stage -------------------------------------------------
n = kernelgen.install(letters=("S", "D"), trans=("NN", "NT"),
                      interpret=True, max_per_family=8)
print(f"install-time stage: built {n} kernels "
      f"(full table: {len(kernelgen.full_table())} TPU signatures, "
      f"{paper_table.total_kernels()} in the paper's ARMv8 TABLE I)")

# -- 2. run-time stage: the paper's Fig. 2 example --------------------------
t = tile_armv8(15, 15, "S", "NN", "dp")
print(f"15x15 SGEMM_NN tiling: coeff={t.coeff} "
      f"(paper reports {paper_table.PAPER_FIG2_IAAT_COEFF}; "
      f"traditional 105), blocks={[(b.m, b.n) for b in t.blocks]}")

# ONE routing API for every GEMM shape: install a Policy once, then every
# entry (2-D gemm, ND matmul, grouped) consults the same Router.
policy = api.install(api.Policy(backend="pallas", interpret=True))
d = api.route("gemm", (45, 77, 33), "S")
print(f"route(gemm, 45x77x33) -> use_pallas={d.use_pallas} "
      f"source={d.source!r} (precedence: forced > profile > analytical)")

p = plan.build_plan(45, 77, 33, "S", "NN")
print(f"execution plan for 45x77x33: {p.num_kernel_calls} kernel call(s), "
      f"memops={p.memops()}")

# -- 3. execute -------------------------------------------------------------
rng = np.random.RandomState(0)
a = jnp.asarray(rng.randn(45, 33), jnp.float32)
b = jnp.asarray(rng.randn(33, 77), jnp.float32)
t0 = time.perf_counter()
out = api.gemm(a, b)                      # routed by the installed Policy
dt = time.perf_counter() - t0
err = float(jnp.abs(out - ref.ref_gemm(a, b)).max())
print(f"IAAT path: maxerr={err:.2e} (interpret mode, {dt * 1e3:.0f} ms)")

# -- 4. vs the traditional pack pipeline ------------------------------------
trad = dispatch.traditional_gemm(a, b, interpret=True)
print(f"traditional pack path: maxerr="
      f"{float(jnp.abs(trad - ref.ref_gemm(a, b)).max()):.2e}")
m = cost.pack_cost_model(16, 16, 16, itemsize=4)
print(f"pack-step share of a 16^3 GEMM (model): "
      f"{m['pack_fraction'] * 100:.0f}% — what IAAT removes")
