"""Serve a small model with continuous batching; decode-time projections
route through IAAT small-GEMM dispatch (the paper's serving use case).

    PYTHONPATH=src python examples/serve_lm.py
"""
import logging
import time

import jax
import numpy as np

from repro import api, configs, obs
from repro.models.registry import build
from repro.serve import PagedEngine, Request

logging.basicConfig(level=logging.INFO)

cfg = configs.get_smoke("glm4-9b")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

# one Policy installed at model entry; the engine snapshots it (swap in
# named_policy("tuned") after `python -m repro.tune` to serve off the
# measured DeviceProfile).  PagedEngine is the production serving path
# for every decoder-only family — paged KV blocks + per-slot recurrent
# state + slot-level scheduling; try cfg = get_smoke("mamba2-780m") or
# "zamba2-7b" to serve a recurrent backbone through the same engine.
api.install(api.named_policy("xla"))
batcher = PagedEngine(model, params, slots=4, max_len=128,
                      temperature=0.8, seed=0, block_size=16)
rng = np.random.RandomState(0)
t0 = time.time()
for rid in range(10):
    prompt = rng.randint(0, cfg.vocab, rng.randint(4, 20)).astype(np.int32)
    batcher.submit(Request(rid, prompt, max_new=24))
done = batcher.run()
dt = time.time() - t0
tokens = sum(len(v) for v in done.values())
for rid in sorted(done)[:3]:
    print(f"req {rid}: {done[rid][:10]} ...")
print(f"{len(done)} requests, {tokens} tokens, {tokens / dt:.1f} tok/s")

# everything above was traced through repro.obs — dump the metrics the
# engine recorded (ttft/e2e percentiles, slot occupancy, queue depth,
# blocks in use, preemptions)
print(obs.report_str())
