"""End-to-end driver: train a ~100M-param OLMo-family model for a few
hundred steps on CPU with checkpointing + fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The same launcher drives the production mesh: swap --smoke for the full
config and add --production-mesh on a real pod.)
"""
import argparse
import dataclasses
import logging

from repro import configs
from repro.launch.train import build_args, run

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M params: olmo family, 8 layers x 768
import repro.configs.olmo_1b as olmo
cfg100m = dataclasses.replace(
    olmo.CONFIG, name="olmo-100m", n_layers=8, d_model=768, n_heads=12,
    n_kv_heads=12, head_dim=64, d_ff=3072, vocab=50304, remat="none")
# register it as the smoke config so the CLI picks it up
olmo.smoke = lambda: cfg100m

out = run(build_args([
    "--arch", "olmo-1b", "--smoke",
    "--backend", "xla",               # any repro.api.POLICY_NAMES entry
    "--steps", str(args.steps),
    "--batch", "8", "--seq", "256",
    "--lr", "6e-4", "--warmup", "50",
    "--accum", "2",
    "--ckpt-dir", args.ckpt, "--ckpt-every", "100",
    "--log-every", "20",
]))
print(f"final step {out['final_step']}, loss {out['loss']:.4f}, "
      f"monitor {out['monitor']}")
assert out["loss"] < 11.0, "loss should be well below ln(V)=10.8 by now"
