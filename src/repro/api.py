"""One routing API: ``Policy`` + ``Router`` for every GEMM shape.

The paper's thesis is that *one* input-aware decision layer should pick
the kernel for every small GEMM.  This module is that layer:

* :class:`Policy` — one frozen config merging the old
  ``dispatch.DispatchConfig`` (backend / interpret / method / thresholds)
  and ``models.common.Backend`` (kernel family / iaat flag).  There is
  exactly one ambient policy (a contextvar, installed once at model
  entry with :func:`install` or scoped with :func:`using`) and every
  entry point takes a per-call ``policy=`` override — no more
  re-entering a context manager on every projection.

* :class:`Router` — generalises the 2-D ``decide()`` to an op-shaped
  ``route(op, dims, dtype) -> Decision`` covering ``gemm`` (2-D BLAS),
  ``matmul`` (ND, leading batch dims, vmap-safe), ``batched_gemm``
  (equal-capacity grouped) and ``ragged_gemm`` (group-contiguous rows).
  Grouped block selection flows through the Decision: the measured
  DeviceProfile entry for the per-group (C, K, N) problem when one
  exists (``backend="tuned"``), the analytical ``pick_blocks`` table
  lookup otherwise — so ``repro.tune`` profiles steer the MoE
  expert-FFN and serving decode paths, not just the 2-D entry.

Decision precedence, uniform across ops (DESIGN.md §Policy & Router):

    forced (backend="pallas"/"xla")  >  profile (backend="tuned")
                                     >  analytical (smallness criterion)

Executors (:func:`gemm`, :func:`matmul`, :func:`batched_gemm`,
:func:`ragged_gemm`) act on the Decision so callers never branch on
backend themselves.  (The pre-Policy entry points — ``dispatch.iaat_gemm``,
``dispatch.configure``, ``models.common.Backend``, ``ops.gemm_jit`` —
are gone; the migration table lives in DESIGN.md §Policy & Router.)

Every ``route`` call is recorded in :data:`repro.obs.ROUTES` — the
shape histogram that seeds traffic-aware tuning — and memoized through
the same entry (see ``Router.route``).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import kernelgen, paper_table, plan as plan_mod

# TPU scale factor for the smallness thresholds: the paper's 80/32 bounds
# are where pack+boundary overheads stop mattering on a 128-bit SIMD unit;
# on a 128x128 MXU the equivalent crossover sits ~4x higher (napkin math in
# DESIGN.md; revisited empirically via repro.tune).
TPU_SCALE = 4.0

#: Op kinds the router understands, with their ``dims`` convention:
#:   gemm          (M, N, K)            2-D BLAS entry
#:   matmul        (*lead, K, N)        x.shape + (N,); M = prod(lead)
#:   batched_gemm  (G, C, K, N)         per-group problem is (C, K, N)
#:   ragged_gemm   (G, bm, K, N)        per-tile problem is (bm, K, N)
OPS = ("gemm", "matmul", "batched_gemm", "ragged_gemm")
_GROUPED = ("batched_gemm", "ragged_gemm")


@dataclasses.dataclass(frozen=True)
class Policy:
    """The single routing policy every GEMM-shaped op consults.

    ``backend`` picks the routing mode (how use-pallas is decided);
    ``kernels`` picks the non-GEMM kernel family (flash attention, SSD
    scan) — empty string derives it from ``backend``; ``iaat=False``
    short-circuits framework matmuls straight to ``jnp.matmul`` (the
    multi-pod dry-run mode that must stay XLA-compilable end to end).
    """
    backend: str = "auto"          # pallas | xla | auto | tuned
    interpret: bool = True         # pallas interpret mode (CPU container)
    method: str = "dp"             # tiler: dp (ours) | greedy (paper)
    paper_thresholds: bool = False  # use the ARMv8 80/32 bounds verbatim
    max_plan_regions: int = 64     # sanity valve
    iaat: bool = True              # False: model matmuls bypass the router
    kernels: str = ""              # "pallas"|"xla"; "" = derive from backend

    def threshold(self, trans: str) -> float:
        base = (paper_table.PAPER_SMALL_THRESHOLD_TN if trans == "TN"
                else paper_table.PAPER_SMALL_THRESHOLD)
        return base if self.paper_thresholds else base * TPU_SCALE

    @property
    def kind(self) -> str:
        """Non-GEMM kernel family (the old ``Backend.kind``).  Derived
        when not pinned: every IAAT-capable backend implies the pallas
        family; only a forced-XLA policy drops to the reference paths."""
        return self.kernels or ("xla" if self.backend == "xla"
                                else "pallas")

    @property
    def pallas(self) -> bool:
        """True when attention/SSD use the Pallas kernels."""
        return self.kind == "pallas"

    def replace(self, **kw) -> "Policy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Decision:
    """How one op was routed — inspectable, so tests and the tune report
    can prove whether a profile (vs the analytical model) decided."""
    use_pallas: bool
    source: str                    # "forced" | "profile" | "analytical"
    op: str = "gemm"
    sig: Optional["kernelgen.KernelSig"] = None   # tuned 2-D plan override
    blocks: Optional[Tuple[int, int, int]] = None  # grouped (bm, bn, bk)


# --------------------------------------------------------------------------
# The ambient policy: one contextvar + a process default, installed once.
# --------------------------------------------------------------------------

_DEFAULT = Policy()
_POLICY: contextvars.ContextVar[Optional[Policy]] = \
    contextvars.ContextVar("repro_policy", default=None)


def current_policy() -> Policy:
    """The policy in effect: scoped override > installed default."""
    return _POLICY.get() or _DEFAULT


def install(policy: Optional[Policy] = None, **kw) -> Policy:
    """Set the process-wide default policy (model/launcher entry; call
    once — per-call overrides and :func:`using` scopes layer on top)."""
    global _DEFAULT
    _DEFAULT = (policy or _DEFAULT).replace(**kw) if kw else \
        (policy or _DEFAULT)
    return _DEFAULT


@contextlib.contextmanager
def using(policy: Optional[Policy] = None, **kw):
    """Scoped policy override (replaces the old per-call
    ``dispatch.configure`` churn for tests/benchmarks)."""
    base = policy or current_policy()
    tok = _POLICY.set(base.replace(**kw) if kw else base)
    try:
        yield current_policy()
    finally:
        _POLICY.reset(tok)


def _resolve(policy: Optional[Policy]) -> Policy:
    return policy if policy is not None else current_policy()


#: CLI / launcher backend names -> Policy (one place, so every entry
#: point — train, serve, examples — accepts the same set).
POLICY_NAMES = ("xla", "pallas", "auto", "tuned")


def named_policy(name: str, *, interpret: bool = True) -> Policy:
    """Build the Policy a launcher flag means.

    ``xla``    — forced XLA everywhere (the multi-pod dry-run mode).
    ``pallas`` — pallas kernels with input-aware GEMM routing (the old
                 ``Backend("pallas", iaat=True)``).
    ``auto``   — same routing, kernel family derived.
    ``tuned``  — route by the measured DeviceProfile (repro.tune).
    """
    if name == "xla":
        return Policy(backend="xla", kernels="xla", iaat=False,
                      interpret=interpret)
    if name == "pallas":
        return Policy(backend="auto", kernels="pallas", iaat=True,
                      interpret=interpret)
    if name in ("auto", "tuned"):
        return Policy(backend=name, iaat=True, interpret=interpret)
    raise ValueError(f"unknown policy name {name!r}; "
                     f"expected one of {POLICY_NAMES}")


def small_enough(M: int, N: int, K: int, trans: str = "NN",
                 policy: Optional[Policy] = None) -> bool:
    """The paper's input-aware criterion: cbrt(MNK) <= threshold."""
    pol = _resolve(policy)
    return (M * N * K) ** (1.0 / 3.0) <= pol.threshold(trans)


# --------------------------------------------------------------------------
# The router.
# --------------------------------------------------------------------------

def _letter_of(dtype) -> str:
    if isinstance(dtype, str):
        return dtype
    return kernelgen.blas_letter(dtype)


def _grouped_problem(op: str, dims) -> Tuple[int, int, int, int]:
    if len(dims) != 4:
        raise ValueError(f"{op} dims must be (G, C|bm, K, N), got {dims}")
    G, C, K, N = (int(d) for d in dims)
    return G, C, K, N


class Router:
    """Routes every GEMM-shaped op through one decision path.

    A Router optionally pins a policy (else it reads the ambient one per
    call); ``route`` is pure w.r.t. its arguments + the active
    DeviceProfile, so traced callers can consult it at trace time.
    """

    def __init__(self, policy: Optional[Policy] = None):
        self._policy = policy

    @property
    def policy(self) -> Policy:
        return _resolve(self._policy)

    # -- decisions ---------------------------------------------------------

    def route(self, op: str, dims, dtype, trans: str = "NN") -> Decision:
        """Route one problem: forced backends first, then the measured
        DeviceProfile (``tuned`` mode), then the analytical criterion.

        Fallback order (DESIGN.md §Tuning): a ``tuned`` backend with no
        profile on disk, or with no entry for this size class, degrades
        to exactly the ``auto`` analytical decision — tuning can only
        ever refine the dispatch, never strand it.

        With observability on (the default), every call lands in the
        ``obs.ROUTES`` shape log — the observed input distribution that
        seeds traffic-aware tuning.  The log entry doubles as a decision
        memo: a decision is pure in (op, dims, dtype, trans), the
        resolved Policy *object* (held by identity — frozen, so identity
        implies equal fields) and the active-DeviceProfile generation,
        so a repeat shape is one dict hit instead of a recompute.
        ``REPRO_OBS=0`` bypasses all of it with one attribute check."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
        pol = self.policy
        rl = obs.ROUTES
        if rl.on:
            letter = dtype if type(dtype) is str else \
                kernelgen.blas_letter(dtype)
            key = (op, letter, trans, tuple(dims), id(pol))
            h = rl.hits.get(key)
            if h is not None and h[1] is pol and h[2] == rl.gen:
                h[0] += 1
                return h[3]
            d = self._decide(op, dims, letter, trans, pol)
            rl.note(key, pol, d)
            # memo-miss only: the flight recorder sees every NEW shape
            # (and every recompute after a profile swap) while the hot
            # repeat-shape path above stays one dict probe
            obs.TRACE.emit("ROUTE_MISS",
                           arg=(op, letter, trans, list(key[3]), d.source))
            return d
        return self._decide(op, dims, _letter_of(dtype), trans, pol)

    def _decide(self, op: str, dims, letter: str, trans: str,
                pol: Policy) -> Decision:
        """The actual decision procedure (memoized via ``route``)."""
        if op in _GROUPED:
            return self._route_grouped(op, dims, letter, pol)
        if op == "matmul":
            if len(dims) < 2:
                raise ValueError(f"matmul dims must be (*lead, K, N), "
                                 f"got {dims}")
            lead, K, N = dims[:-2], int(dims[-2]), int(dims[-1])
            M = 1
            for d in lead:
                M *= int(d)
            dims = (M, N, K)
        M, N, K = (int(d) for d in dims)
        if pol.backend == "pallas":
            return Decision(True, "forced", op)
        if pol.backend == "xla":
            return Decision(False, "forced", op)
        if pol.backend == "tuned":
            entry = self._profile_entry(M, N, K, letter, trans)
            if entry is not None:
                if entry.prefer_pallas:
                    return Decision(True, "profile", op, sig=entry.sig)
                return Decision(False, "profile", op)
        return Decision(small_enough(M, N, K, trans, pol), "analytical", op)

    def _route_grouped(self, op: str, dims, letter: str,
                       pol: Policy) -> Decision:
        """Grouped ops: the per-group (C, K, N) problem is the routing
        unit; the block choice travels in ``Decision.blocks`` (always
        populated — kernel entries need blocks even under a forced
        backend).  Ragged keeps the caller's row block: group sizes are
        traced, so only (bn, bk) may come from the profile."""
        from repro.kernels import grouped_gemm as _gg
        G, C, K, N = _grouped_problem(op, dims)
        dtype = kernelgen.BLAS_DTYPES.get(
            letter, kernelgen.FRAMEWORK_DTYPES.get(letter))
        analytical = _gg.pick_blocks(C, K, N, dtype)
        if op == "ragged_gemm":
            analytical = (C,) + analytical[1:]
        if pol.backend == "pallas":
            return Decision(True, "forced", op, blocks=analytical)
        if pol.backend == "xla":
            return Decision(False, "forced", op, blocks=analytical)
        if pol.backend == "tuned":
            # grouped kernels consume operands as stored — trans is NN.
            # Prefer an entry measured ON the grouped kernel (the online
            # tuner's ``grouped:``-namespace sweep); fall back to the
            # 2-D timing of the per-group shape for older profiles.
            entry = self._grouped_profile_entry(C, N, K, letter)
            if entry is not None:
                blocks = analytical
                if entry.sig is not None:
                    blocks = (entry.sig.bm, entry.sig.bn, entry.sig.bk)
                    if op == "ragged_gemm":
                        blocks = (C, entry.sig.bn, entry.sig.bk)
                return Decision(entry.prefer_pallas, "profile", op,
                                sig=entry.sig, blocks=blocks)
        return Decision(small_enough(C, N, K, "NN", pol), "analytical", op,
                        blocks=analytical)

    @staticmethod
    def _profile_entry(M, N, K, letter, trans):
        from repro.tune import profile as profile_mod
        prof = profile_mod.active_profile()
        if prof is None:
            return None
        entry = prof.lookup_dims(M, N, K, letter, trans)
        if entry is None or not entry.measured:
            return None
        return entry

    @staticmethod
    def _grouped_profile_entry(C, N, K, letter):
        from repro.tune import profile as profile_mod
        prof = profile_mod.active_profile()
        if prof is None:
            return None
        entry = prof.lookup_grouped_dims(C, N, K, letter)
        if entry is None or not entry.measured:
            entry = prof.lookup_dims(C, N, K, letter, "NN")
        if entry is None or not entry.measured:
            return None
        return entry


_ROUTER = Router()


def route(op: str, dims, dtype, trans: str = "NN",
          policy: Optional[Policy] = None) -> Decision:
    """Module-level convenience over a shared :class:`Router`."""
    if policy is None:
        return _ROUTER.route(op, dims, dtype, trans)
    return Router(policy).route(op, dims, dtype, trans)


# --------------------------------------------------------------------------
# Executors: act on the Decision so callers never branch on backend.
# --------------------------------------------------------------------------

def _trans_str(trans_a: bool, trans_b: bool) -> str:
    return ("T" if trans_a else "N") + ("T" if trans_b else "N")


def _problem_dims(a_shape, b_shape, trans: str):
    M, Ka = (a_shape[1], a_shape[0]) if trans[0] == "T" else a_shape
    Kb, N = (b_shape[1], b_shape[0]) if trans[1] == "T" else b_shape
    if Ka != Kb:
        raise ValueError(f"K mismatch: {a_shape} {trans[0]} vs "
                         f"{b_shape} {trans[1]}")
    return M, N, Ka


def _xla_gemm(a, b, c, alpha, beta, trans: str):
    """XLA epilogue mirrors the Pallas ``epilogue_axpby`` template exactly:
    beta*c is accumulated in the accumulator dtype BEFORE the cast to
    result_type(a, b), so a ``c`` of any dtype cannot promote/demote the
    output relative to the kernel path."""
    opa = a.T if trans[0] == "T" else a
    opb = b.T if trans[1] == "T" else b
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    acc = jnp.dot(opa, opb,
                  preferred_element_type=jnp.promote_types(
                      a.dtype, jnp.float32)
                  if not jnp.issubdtype(a.dtype, jnp.complexfloating)
                  else None)
    out = alpha * acc
    if c is not None:
        out = out + beta * c.astype(out.dtype)
    return out.astype(out_dtype)


def _plan_gemm(pol: Policy, d: Decision, a, b, c, alpha, beta, trans: str):
    M, N, K = _problem_dims(a.shape, b.shape, trans)
    letter = kernelgen.blas_letter(jnp.result_type(a.dtype, b.dtype))
    p = plan_mod.build_plan(M, N, K, letter, trans, pol.method,
                            override=d.sig)
    if p.num_kernel_calls > pol.max_plan_regions:
        return _xla_gemm(a, b, c, alpha, beta, trans)
    return plan_mod.execute(p, a, b, c, alpha, beta,
                            interpret=pol.interpret)


def gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None,
         alpha=1.0, beta=0.0, trans_a: bool = False, trans_b: bool = False,
         *, policy: Optional[Policy] = None) -> jax.Array:
    """C = alpha * op(A) @ op(B) + beta * C with input-aware routing
    (the 2-D BLAS entry — the paper's ``iaat_gemm``)."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("gemm is the 2-D BLAS entry; use matmul()")
    pol = _resolve(policy)
    trans = _trans_str(trans_a, trans_b)
    M, N, K = _problem_dims(a.shape, b.shape, trans)
    letter = kernelgen.blas_letter(jnp.result_type(a.dtype, b.dtype))
    d = route("gemm", (M, N, K), letter, trans, policy=pol)
    if not d.use_pallas:
        return _xla_gemm(a, b, c, alpha, beta, trans)
    return _plan_gemm(pol, d, a, b, c, alpha, beta, trans)


def matmul(x: jax.Array, w: jax.Array, *,
           policy: Optional[Policy] = None) -> jax.Array:
    """Framework matmul: (..., K) @ (K, N) with IAAT routing.

    Leading dims of ``x`` flatten into M (vmap-safe: shapes are concrete
    at trace time, and the flatten/unflatten is a pure reshape).  This is
    the hook through which every model projection reaches the paper's
    technique."""
    pol = _resolve(policy)
    if not pol.iaat:
        return jnp.matmul(x, w)
    letter = kernelgen.blas_letter(jnp.result_type(x.dtype, w.dtype))
    d = route("matmul", tuple(x.shape) + (w.shape[-1],), letter,
              policy=pol)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if not d.use_pallas:
        # same epilogue as the declined gemm() path (f32-preferred
        # accumulation, one cast) so both entries agree numerically
        out = _xla_gemm(x2, w, None, 1.0, 0.0, "NN")
    else:
        out = _plan_gemm(pol, d, x2, w, None, 1.0, 0.0, "NN")
    return out.reshape(lead + (w.shape[-1],))


def batched_gemm(x: jax.Array, w: jax.Array, *,
                 policy: Optional[Policy] = None) -> jax.Array:
    """Equal-capacity grouped GEMM: x (G, C, K) @ w (G, K, N) -> (G, C, N),
    routed per the per-group problem; falls back to a batched einsum when
    the decision is XLA."""
    pol = _resolve(policy)
    G, C, K = x.shape
    N = w.shape[-1]
    d = route("batched_gemm", (G, C, K, N),
              jnp.result_type(x.dtype, w.dtype), policy=pol)
    if not d.use_pallas:
        return jnp.einsum("gck,gkn->gcn", x, w)
    from repro.kernels import grouped_gemm as _gg
    return _gg.batched_gemm(x, w, interpret=pol.interpret, blocks=d.blocks)


def ragged_gemm(x: jax.Array, w: jax.Array, tile_group_ids: jax.Array,
                *, bm: int = 128,
                policy: Optional[Policy] = None) -> jax.Array:
    """Ragged grouped GEMM (group-contiguous rows, traced group sizes):
    x (T, K) @ w (G, K, N) -> (T, N); XLA fallback gathers each tile's
    expert weight and einsums."""
    pol = _resolve(policy)
    T, K = x.shape
    G, _, N = w.shape
    d = route("ragged_gemm", (G, bm, K, N),
              jnp.result_type(x.dtype, w.dtype), policy=pol)
    if not d.use_pallas:
        wt = w[tile_group_ids]                    # (T//bm, K, N)
        xt = x.reshape(-1, bm, K)
        return jnp.einsum("tbk,tkn->tbn", xt, wt).reshape(T, N)
    from repro.kernels import grouped_gemm as _gg
    return _gg.ragged_gemm(x, w, tile_group_ids, bm=bm,
                           interpret=pol.interpret, blocks=d.blocks)
