"""Config registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Arch ids use the assignment's dashed names; module files use underscores.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,
                                shape_applicable)

ARCH_IDS: List[str] = [
    "mixtral-8x22b",
    "moonshot-v1-16b-a3b",
    "mamba2-780m",
    "zamba2-7b",
    "glm4-9b",
    "gemma3-1b",
    "olmo-1b",
    "smollm-360m",
    "seamless-m4t-large-v2",
    "internvl2-2b",
]


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()


def all_cells():
    """Every assigned (arch, shape) cell with its applicability verdict."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
