"""Model / shape configuration schema for the framework.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<arch>.py`` (exact numbers from the assignment) plus a
``smoke()`` reduction of the same family for CPU tests.  ``ShapeConfig``
encodes the assigned input-shape set (train_4k / prefill_32k / decode_32k /
long_500k).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Vocab padded for TP divisibility (logical vocab kept for the loss)."""
    return -(v // -multiple) * multiple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int                  # N
    head_dim: int = 64            # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class AttentionPattern:
    """Per-layer attention kind schedule.

    kind: "full" | "swa" (all layers windowed) | "local_global"
    (local_ratio local layers per 1 global) | "none" (attention-free).
    """
    kind: str = "full"
    window: Optional[int] = None
    local_ratio: int = 0          # e.g. 5 for gemma3's 5:1

    def is_subquadratic(self) -> bool:
        return self.kind in ("swa", "none")

    def layer_is_global(self, i: int) -> bool:
        if self.kind == "local_global":
            return (i % (self.local_ratio + 1)) == self.local_ratio
        return self.kind == "full"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    attn: AttentionPattern = AttentionPattern()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): one shared attention block reused every
    # ``shared_attn_every`` layers
    shared_attn_every: int = 0
    # enc-dec
    n_encoder_layers: int = 0
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None          # None | "audio" | "vision"
    frontend_tokens: int = 0                # prefix length contributed
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    parametric_norm: bool = True            # olmo: False
    tie_embeddings: bool = False
    # compute policy
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"                     # full | dots | none
    # paper integration: route small GEMMs through IAAT dispatch
    iaat_dispatch: bool = True
    # §Perf: pad attention heads (with ZERO-initialised dead heads) up to
    # a multiple compatible with the model axis, preserving the GQA
    # pairing (H_pad = lcm(rep, mult)-multiple).  Dead heads contribute
    # exactly 0 forward AND receive exactly 0 gradient (their q/k/v and
    # wo rows stay 0), so the math is unchanged while attention shards.
    head_pad_multiple: int = 0

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def n_heads_padded(self) -> int:
        if not self.n_heads or not self.head_pad_multiple:
            return self.n_heads
        rep = self.n_heads // self.n_kv_heads
        step = rep * self.head_pad_multiple // math.gcd(
            rep, self.head_pad_multiple)
        return -(self.n_heads // -step) * step

    @property
    def n_kv_heads_padded(self) -> int:
        if not self.n_kv_heads or not self.head_pad_multiple:
            return self.n_kv_heads
        rep = self.n_heads // self.n_kv_heads
        return self.n_heads_padded // rep

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim_
        n = 0
        emb = self.vocab_padded * d
        n += emb * (1 if self.tie_embeddings else 2)
        is_hybrid = self.shared_attn_every > 0

        def attn_params():
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
                self.n_heads * hd * d

        def mlp_params(dff):
            return 3 * d * dff  # gated (SwiGLU)

        if self.family in ("dense", "vlm", "audio", "encdec"):
            per = attn_params() + mlp_params(self.d_ff) + 2 * d
            n += per * L
            if self.family == "encdec":
                per_dec = attn_params() * 2 + mlp_params(self.d_ff) + 3 * d
                n += per_dec * self.n_encoder_layers  # decoder stack
        elif self.family == "moe":
            m = self.moe
            per = attn_params() + 2 * d + d * m.num_experts  # router
            per += m.num_experts * 3 * d * m.d_expert
            n += per * L
        elif self.family in ("ssm", "hybrid"):
            di, s = self.d_inner, self.ssm
            nh = self.ssm_heads
            per = d * (2 * di + 2 * s.d_state + nh)   # in_proj(z,x,B,C,dt)
            per += s.d_conv * (di + 2 * s.d_state)    # conv
            per += nh * 2                             # A_log, D
            per += di * d + 2 * d                     # out_proj + norms
            n += per * L
            if is_hybrid:
                n += attn_params() + mlp_params(self.d_ff) + 2 * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        expert_all = m.num_experts * 3 * self.d_model * m.d_expert * self.n_layers
        expert_act = m.top_k * 3 * self.d_model * m.d_expert * self.n_layers
        return full - expert_all + expert_act


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        sub = cfg.attn.is_subquadratic() or cfg.family in ("ssm", "hybrid") \
            or cfg.attn.kind == "local_global"
        if not sub:
            return False, "pure full-attention arch: long_500k skipped per assignment"
    if shape.kind == "decode" and cfg.family == "encdec" \
            and shape.name == "long_500k":
        return False, "enc-dec 500k decode not meaningful"
    return True, ""
