"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt]"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    attn=AttentionPattern(kind="local_global", window=512, local_ratio=5),
    rope_theta=1e6,
    tie_embeddings=True,
    # §Perf: zero-padded dead heads (H 4->16, kv 1->4) shard attention
    # 16-ways at a 4x padded-compute cost — net ~4x (see smollm note)
    head_pad_multiple=16,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-smoke", n_layers=4, d_model=64, n_heads=2,
        n_kv_heads=1, head_dim=32, d_ff=128, vocab=512,
        attn=AttentionPattern(kind="local_global", window=16, local_ratio=1))
