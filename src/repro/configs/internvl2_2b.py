"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2.  [arXiv:2404.16821; hf]

Backbone = the InternLM2-1.8B decoder; the InternViT frontend is a stub
(1024 precomputed patch embeddings prepended per the assignment).
"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    attn=AttentionPattern(kind="full"),
    frontend="vision",
    frontend_tokens=1024,
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, frontend_tokens=8)
