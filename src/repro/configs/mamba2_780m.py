"""mamba2-780m [ssm] — 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 (SSD, state-space duality).  [arXiv:2405.21060]"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    attn=AttentionPattern(kind="none"),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=128),
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=8, expand=2, d_conv=4, chunk=16))
