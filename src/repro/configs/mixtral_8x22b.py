"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA.  [arXiv:2401.04088; hf]"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    attn=AttentionPattern(kind="swa", window=4096),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16384),
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        attn=AttentionPattern(kind="swa", window=32),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                      capacity_factor=4.0))
