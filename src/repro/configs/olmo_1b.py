"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192
vocab=50304 — non-parametric LN.  [arXiv:2402.00838; hf]"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab=50304,
    attn=AttentionPattern(kind="full"),
    parametric_norm=False,          # OLMo's non-parametric LayerNorm
    tie_embeddings=True,
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="olmo-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=256)
