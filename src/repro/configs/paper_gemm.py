"""The paper's own 'architecture': the small-GEMM benchmark suite.

IAAT's evaluation object is not a neural network but the S/D/C/Z x
NN/NT/TN/TT small-GEMM grid (paper §VI).  This config pins that grid so
benchmarks and examples share one definition of the paper's workload.
"""
import dataclasses
from typing import Tuple

from repro.core.paper_table import (PAPER_SMALL_THRESHOLD,
                                    PAPER_SMALL_THRESHOLD_TN)


@dataclasses.dataclass(frozen=True)
class PaperGemmConfig:
    letters: Tuple[str, ...] = ("S", "D", "C", "Z")
    transpositions: Tuple[str, ...] = ("NN", "NT", "TN", "TT")
    # M = N = K sweep bounds per the paper's smallness definition
    max_n: int = PAPER_SMALL_THRESHOLD          # 80 (non-TN)
    max_n_tn: int = PAPER_SMALL_THRESHOLD_TN    # 32 (TN)
    step: int = 2

    def sizes(self, trans: str):
        lim = self.max_n_tn if trans == "TN" else self.max_n
        return range(self.step, lim + 1, self.step)


CONFIG = PaperGemmConfig()


def smoke() -> PaperGemmConfig:
    return dataclasses.replace(CONFIG, letters=("S",), max_n=16, max_n_tn=8)
