"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]

Backbone only per the assignment: 24 encoder + 24 decoder layers; the
audio frontend is a stub (precomputed frame embeddings from input_specs).
"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                  # decoder stack
    n_encoder_layers=24,          # encoder stack
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    attn=AttentionPattern(kind="full"),
    frontend="audio",
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-smoke", n_layers=2, n_encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=512)
