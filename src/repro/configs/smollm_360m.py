"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small.  [hf:HuggingFaceTB/SmolLM; hf]

15 heads / kv=5: indivisible by a 16-way model axis — the sharding rules
replicate attention and shard MLP/vocab (see parallel/rules.py), which is
exactly the kind of odd-size case IAAT's boundary-free kernels target.
"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    attn=AttentionPattern(kind="full"),
    tie_embeddings=True,
    rope_theta=1e4,
    # §Perf: 15 heads never divide a 2^k model axis; zero-padded dead
    # heads (H 15->48, kv 5->16, GQA pairing preserved) let attention
    # shard 16-ways at a 3.2x padded-compute cost — net ~5x
    head_pad_multiple=16,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-smoke", n_layers=2, d_model=60, n_heads=3,
        n_kv_heads=1, head_dim=20, d_ff=96, vocab=256)
