"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
(applied every 6 layers, weights shared).  [arXiv:2411.15242]"""
import dataclasses

from repro.configs.base import AttentionPattern, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    attn=AttentionPattern(kind="full"),
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=128),
    shared_attn_every=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=8, expand=2, d_conv=4, chunk=16),
        shared_attn_every=2)
