"""Cost models: the paper's memops objective (§V-A) + TPU roofline terms.

The run-time tiler minimizes data movement from the cache level feeding the
compute units into the compute units:

    memops(blocks, K) = sum_i (m_i + n_i) * K  +  2 * M * N      (paper eq.)

(the K term = A-panel + B-panel loads per C block; 2MN = read+write of C).
On TPU the same objective governs HBM->VMEM traffic of an unpacked GEMM, so
the objective transfers unchanged; only the feasible block set differs.

Also hosts the napkin-math roofline helpers used by benchmarks and the
perf log (§Perf): v5e peak numbers are the graded hardware constants.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

# --- graded hardware constants (TPU v5e) ---------------------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 2
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
VMEM_BW = 8 * HBM_BW            # ~order-of-magnitude VMEM advantage


def memops_blocks(blocks: Iterable[Tuple[int, int]], K: int, M: int,
                  N: int) -> int:
    """The paper's exact objective: Σ(m_i+n_i)·K + 2·M·N."""
    s = sum(m + n for m, n in blocks)
    return s * K + 2 * M * N


def memops_coeff(blocks: Iterable[Tuple[int, int]]) -> int:
    """Just the K coefficient Σ(m_i+n_i) (what the tiler minimizes)."""
    return sum(m + n for m, n in blocks)


def gemm_flops(M: int, N: int, K: int, complex_: bool = False) -> int:
    """Paper eq. (1)/(2): 2MNK real, 8MNK complex (they count 4x)."""
    return (8 if complex_ else 2) * M * N * K


@dataclasses.dataclass(frozen=True)
class RooflineEstimate:
    flops: float
    hbm_bytes: float
    compute_s: float
    memory_s: float

    @property
    def bound(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)


def gemm_roofline(M: int, N: int, K: int, itemsize: int, *,
                  complex_: bool = False, peak=PEAK_FLOPS_BF16,
                  traffic_bytes: float | None = None) -> RooflineEstimate:
    flops = gemm_flops(M, N, K, complex_)
    planes = 2 if complex_ else 1
    if traffic_bytes is None:
        traffic_bytes = (M * K + K * N + 2 * M * N) * itemsize * planes
    return RooflineEstimate(flops, traffic_bytes, flops / peak,
                            traffic_bytes / HBM_BW)


def pack_cost_model(M: int, N: int, K: int, itemsize: int,
                    peak=PEAK_FLOPS_F32) -> dict:
    """Model of the paper's Fig. 3: fraction of runtime spent packing.

    The traditional pipeline copies A and B into packed buffers
    (read + write = 2x bytes each way) before computing.  The GEMM itself
    runs at min(compute, memory) roofline time.  Small sizes => pack time
    dominates; large sizes => amortised, matching the paper's 67% -> 3%
    exponential decay.
    """
    pack_bytes = 2 * (M * K + K * N) * itemsize
    t_pack = pack_bytes / HBM_BW
    r = gemm_roofline(M, N, K, itemsize, peak=peak)
    t_gemm = max(r.compute_s, r.memory_s)
    frac = t_pack / (t_pack + t_gemm)
    return {"pack_bytes": pack_bytes, "t_pack_s": t_pack,
            "t_gemm_s": t_gemm, "pack_fraction": frac}
