"""Legacy IAAT dispatch entry — now a thin shim over :mod:`repro.api`.

The routing brain (config, smallness criterion, profile consultation,
plan execution) lives in ``repro.api`` as one ``Policy`` + ``Router``
covering every GEMM shape; this module keeps the original names alive:

``DispatchConfig``  — alias of :class:`repro.api.Policy`.
``configure``/``config`` — forward to :func:`repro.api.using` /
                  :func:`repro.api.current_policy`.
``decide``      — the 2-D routing entry, now ``Router.route("gemm", …)``.
``iaat_gemm``   — BLAS-style C = alpha*op(A)@op(B) + beta*C.
``matmul``      — the framework ND entry.
``traditional_gemm`` — the explicit pack-step pipeline (pad + blocked
                  copy + fixed kernel), kept here as the paper's baseline
                  for the Fig. 3 pack-cost benchmark — it is NOT routed,
                  which is the point.

New code should import ``repro.api`` directly (deprecation table in
DESIGN.md §Policy & Router).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import api
from repro.api import (  # noqa: F401  (re-exported compatibility surface)
    Decision, Policy, TPU_SCALE, _xla_gemm, current_policy as config,
    install, using as configure)
from repro.core import kernelgen, vmem

# The old config class is the new Policy, verbatim: same field names,
# same defaults, plus the merged-in ``iaat``/``kernels`` Backend axes.
DispatchConfig = Policy


def small_enough(M: int, N: int, K: int, trans: str = "NN",
                 cfg: Optional[Policy] = None) -> bool:
    """The paper's input-aware criterion: cbrt(MNK) <= threshold."""
    return api.small_enough(M, N, K, trans, cfg)


def decide(M: int, N: int, K: int, letter: str, trans: str,
           cfg: Optional[Policy] = None) -> Decision:
    """Route one 2-D problem (forced > profile > analytical)."""
    return api.route("gemm", (M, N, K), letter, trans, policy=cfg)


def iaat_gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None,
              alpha=1.0, beta=0.0, trans_a: bool = False,
              trans_b: bool = False) -> jax.Array:
    """C = alpha * op(A) @ op(B) + beta * C with input-aware dispatch."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("iaat_gemm is the 2-D BLAS entry; use matmul()")
    return api.gemm(a, b, c, alpha, beta, trans_a, trans_b)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Framework matmul: (..., K) @ (K, N) with IAAT small-GEMM dispatch."""
    return api.matmul(x, w)


# --------------------------------------------------------------------------
# The traditional (pack-step) pipeline — the paper's baseline.
# --------------------------------------------------------------------------

_PACK_SIG = {"S": (128, 256, 256), "D": (64, 128, 128),
             "C": (64, 128, 128), "Z": (32, 128, 128),
             "H": (256, 256, 256)}


def traditional_gemm(a, b, c=None, alpha=1.0, beta=0.0,
                     trans_a: bool = False, trans_b: bool = False,
                     *, interpret: bool = True):
    """Classic block+pack+compute GEMM (paper §I): normalise both operands
    into padded NN layout (the pack step — real extra HBM traffic), then
    run ONE fixed kernel over the padded problem.  Exists to measure what
    IAAT removes."""
    from repro.kernels import iaat_gemm as kmod
    trans = api._trans_str(trans_a, trans_b)
    M, N, K = api._problem_dims(a.shape, b.shape, trans)
    letter = kernelgen.blas_letter(jnp.result_type(a.dtype, b.dtype))
    bm, bn, bk = _PACK_SIG[letter]
    # pack: transpose-normalise + pad to kernel multiples (copies!)
    opa = a.T if trans[0] == "T" else a
    opb = b.T if trans[1] == "T" else b
    Mp, Np, Kp = (vmem.round_up(M, bm), vmem.round_up(N, bn),
                  vmem.round_up(K, bk))
    opa = jnp.pad(opa, ((0, Mp - M), (0, Kp - K)))
    opb = jnp.pad(opb, ((0, Kp - K), (0, Np - N)))
    sig = kernelgen.KernelSig(letter, "NN", bm, bn, bk)
    out = kmod.gemm_region(sig, opa, opb, None, alpha=alpha, beta=0.0,
                           interpret=interpret)[:M, :N]
    if c is not None:
        out = out + jnp.asarray(beta, out.dtype) * c
    return out


def traditional_pack_bytes(M: int, N: int, K: int, dtype) -> int:
    """HBM bytes the pack step moves (read+write both panels)."""
    item = jnp.dtype(dtype).itemsize
    letter = kernelgen.blas_letter(dtype)
    bm, bn, bk = _PACK_SIG[letter]
    Mp, Np, Kp = vmem.round_up(M, bm), vmem.round_up(N, bn), vmem.round_up(K, bk)
    return 2 * (Mp * Kp + Kp * Np) * item
