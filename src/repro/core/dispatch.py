"""The paper's *traditional* (pack-step) GEMM baseline.

The routing brain lives in :mod:`repro.api` (one ``Policy`` + ``Router``
covering every GEMM shape); the deprecation shims that used to forward
the old names (``DispatchConfig``/``configure``/``decide``/``iaat_gemm``)
have been removed — import ``repro.api`` directly.

What remains here is the explicit pack-step pipeline (pad + blocked copy
+ ONE fixed kernel), kept as the paper's §I baseline for the Fig. 3
pack-cost benchmark — it is deliberately NOT routed, which is the point:
it measures what IAAT removes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import api
from repro.core import kernelgen, vmem

_PACK_SIG = {"S": (128, 256, 256), "D": (64, 128, 128),
             "C": (64, 128, 128), "Z": (32, 128, 128),
             "H": (256, 256, 256)}


def traditional_gemm(a, b, c=None, alpha=1.0, beta=0.0,
                     trans_a: bool = False, trans_b: bool = False,
                     *, interpret: bool = True):
    """Classic block+pack+compute GEMM (paper §I): normalise both operands
    into padded NN layout (the pack step — real extra HBM traffic), then
    run ONE fixed kernel over the padded problem.  Exists to measure what
    IAAT removes."""
    from repro.kernels import iaat_gemm as kmod
    trans = api._trans_str(trans_a, trans_b)
    M, N, K = api._problem_dims(a.shape, b.shape, trans)
    letter = kernelgen.blas_letter(jnp.result_type(a.dtype, b.dtype))
    bm, bn, bk = _PACK_SIG[letter]
    # pack: transpose-normalise + pad to kernel multiples (copies!)
    opa = a.T if trans[0] == "T" else a
    opb = b.T if trans[1] == "T" else b
    Mp, Np, Kp = (vmem.round_up(M, bm), vmem.round_up(N, bn),
                  vmem.round_up(K, bk))
    opa = jnp.pad(opa, ((0, Mp - M), (0, Kp - K)))
    opb = jnp.pad(opb, ((0, Kp - K), (0, Np - N)))
    sig = kernelgen.KernelSig(letter, "NN", bm, bn, bk)
    out = kmod.gemm_region(sig, opa, opb, None, alpha=alpha, beta=0.0,
                           interpret=interpret)[:M, :N]
    if c is not None:
        out = out + jnp.asarray(beta, out.dtype) * c
    return out


def traditional_pack_bytes(M: int, N: int, K: int, dtype) -> int:
    """HBM bytes the pack step moves (read+write both panels)."""
    item = jnp.dtype(dtype).itemsize
    letter = kernelgen.blas_letter(dtype)
    bm, bn, bk = _PACK_SIG[letter]
    Mp, Np, Kp = vmem.round_up(M, bm), vmem.round_up(N, bn), vmem.round_up(K, bk)
    return 2 * (Mp * Kp + Kp * Np) * item
