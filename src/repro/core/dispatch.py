"""Public IAAT API + smallness dispatch (ties the two stages together).

``iaat_gemm``   — BLAS-style C = alpha*op(A)@op(B) + beta*C.  Applies the
                  paper's input-aware criterion: small problems run the
                  planned pallas-kernel path (no pack, no boundary code),
                  large problems fall through to XLA's packed GEMM, which
                  is the "traditional BLAS" regime where packing is
                  amortised and correct to prefer.
``matmul``      — the framework entry every model layer routes through.
``traditional_gemm`` — the explicit pack-step pipeline (pad + blocked
                  copy + fixed kernel), kept as the paper's baseline for
                  the Fig. 3 pack-cost benchmark.

Config is a contextvar so tests/benchmarks/models can flip backends
(`xla` for CPU dry-runs, `pallas` with interpret=True for kernel
validation, `pallas` compiled on real TPUs, `tuned` to route by the
measured DeviceProfile from ``repro.tune``) without threading arguments.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import kernelgen, paper_table, plan as plan_mod, vmem

# TPU scale factor for the smallness thresholds: the paper's 80/32 bounds
# are where pack+boundary overheads stop mattering on a 128-bit SIMD unit;
# on a 128x128 MXU the equivalent crossover sits ~4x higher (napkin math in
# DESIGN.md; revisited empirically in EXPERIMENTS.md §Perf).
TPU_SCALE = 4.0


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    backend: str = "auto"          # pallas | xla | auto | tuned
    interpret: bool = True         # pallas interpret mode (CPU container)
    method: str = "dp"             # tiler: dp (ours) | greedy (paper)
    paper_thresholds: bool = False  # use the ARMv8 80/32 bounds verbatim
    max_plan_regions: int = 64     # sanity valve

    def threshold(self, trans: str) -> float:
        base = (paper_table.PAPER_SMALL_THRESHOLD_TN if trans == "TN"
                else paper_table.PAPER_SMALL_THRESHOLD)
        return base if self.paper_thresholds else base * TPU_SCALE


_CONFIG = contextvars.ContextVar("iaat_config", default=DispatchConfig())


def config() -> DispatchConfig:
    return _CONFIG.get()


@contextlib.contextmanager
def configure(**kw):
    tok = _CONFIG.set(dataclasses.replace(_CONFIG.get(), **kw))
    try:
        yield _CONFIG.get()
    finally:
        _CONFIG.reset(tok)


def small_enough(M: int, N: int, K: int, trans: str = "NN",
                 cfg: Optional[DispatchConfig] = None) -> bool:
    """The paper's input-aware criterion: cbrt(MNK) <= threshold."""
    cfg = cfg or config()
    return (M * N * K) ** (1.0 / 3.0) <= cfg.threshold(trans)


@dataclasses.dataclass(frozen=True)
class Decision:
    """How one GEMM call was routed — inspectable, so tests and the tune
    report can prove whether a profile (vs the analytical model) decided."""
    use_pallas: bool
    source: str                    # "forced" | "profile" | "analytical"
    sig: Optional["kernelgen.KernelSig"] = None   # tuned kernel override


def decide(M: int, N: int, K: int, letter: str, trans: str,
           cfg: Optional[DispatchConfig] = None) -> Decision:
    """Route one problem: forced backends first, then the measured
    DeviceProfile (``tuned`` mode), then the analytical criterion.

    Fallback order (DESIGN.md §Tuning): a ``tuned`` backend with no
    profile on disk, or with no entry for this size class, degrades to
    exactly the ``auto`` analytical decision — tuning can only ever
    refine the dispatch, never strand it."""
    cfg = cfg or config()
    if cfg.backend == "pallas":
        return Decision(True, "forced")
    if cfg.backend == "xla":
        return Decision(False, "forced")
    if cfg.backend == "tuned":
        from repro.tune import profile as profile_mod
        prof = profile_mod.active_profile()
        if prof is not None:
            entry = prof.lookup_dims(M, N, K, letter, trans)
            if entry is not None and entry.measured:
                if entry.prefer_pallas:
                    return Decision(True, "profile", entry.sig)
                return Decision(False, "profile")
    return Decision(small_enough(M, N, K, trans, cfg), "analytical")


def _trans_str(trans_a: bool, trans_b: bool) -> str:
    return ("T" if trans_a else "N") + ("T" if trans_b else "N")


def _problem_dims(a_shape, b_shape, trans: str):
    M, Ka = (a_shape[1], a_shape[0]) if trans[0] == "T" else a_shape
    Kb, N = (b_shape[1], b_shape[0]) if trans[1] == "T" else b_shape
    if Ka != Kb:
        raise ValueError(f"K mismatch: {a_shape} {trans[0]} vs {b_shape} {trans[1]}")
    return M, N, Ka


def _xla_gemm(a, b, c, alpha, beta, trans: str):
    opa = a.T if trans[0] == "T" else a
    opb = b.T if trans[1] == "T" else b
    out = alpha * jnp.dot(opa, opb,
                          preferred_element_type=jnp.promote_types(
                              a.dtype, jnp.float32)
                          if not jnp.issubdtype(a.dtype, jnp.complexfloating)
                          else None)
    out = out.astype(jnp.result_type(a.dtype, b.dtype))
    if c is not None:
        out = out + jnp.asarray(beta, c.dtype) * c
    return out


def iaat_gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None,
              alpha=1.0, beta=0.0, trans_a: bool = False,
              trans_b: bool = False) -> jax.Array:
    """C = alpha * op(A) @ op(B) + beta * C with input-aware dispatch."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("iaat_gemm is the 2-D BLAS entry; use matmul()")
    cfg = config()
    trans = _trans_str(trans_a, trans_b)
    M, N, K = _problem_dims(a.shape, b.shape, trans)
    letter = kernelgen.blas_letter(jnp.result_type(a.dtype, b.dtype))
    d = decide(M, N, K, letter, trans, cfg)
    if not d.use_pallas:
        return _xla_gemm(a, b, c, alpha, beta, trans)
    p = plan_mod.build_plan(M, N, K, letter, trans, cfg.method,
                            override=d.sig)
    if p.num_kernel_calls > cfg.max_plan_regions:
        return _xla_gemm(a, b, c, alpha, beta, trans)
    return plan_mod.execute(p, a, b, c, alpha, beta,
                            interpret=cfg.interpret)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Framework matmul: (..., K) @ (K, N) with IAAT small-GEMM dispatch.

    Leading dims of ``x`` are flattened into M.  This is the hook through
    which every model layer (expert FFNs, decode-time projections, …)
    reaches the paper's technique.
    """
    cfg = config()
    if cfg.backend == "xla":
        return jnp.matmul(x, w)
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape((-1, K))
    out = iaat_gemm(x2, w)
    return out.reshape(lead + (w.shape[-1],))


# --------------------------------------------------------------------------
# The traditional (pack-step) pipeline — the paper's baseline.
# --------------------------------------------------------------------------

_PACK_SIG = {"S": (128, 256, 256), "D": (64, 128, 128),
             "C": (64, 128, 128), "Z": (32, 128, 128),
             "H": (256, 256, 256)}


def traditional_gemm(a, b, c=None, alpha=1.0, beta=0.0,
                     trans_a: bool = False, trans_b: bool = False,
                     *, interpret: bool = True):
    """Classic block+pack+compute GEMM (paper §I): normalise both operands
    into padded NN layout (the pack step — real extra HBM traffic), then
    run ONE fixed kernel over the padded problem.  Exists to measure what
    IAAT removes."""
    from repro.kernels import iaat_gemm as kmod
    trans = _trans_str(trans_a, trans_b)
    M, N, K = _problem_dims(a.shape, b.shape, trans)
    letter = kernelgen.blas_letter(jnp.result_type(a.dtype, b.dtype))
    bm, bn, bk = _PACK_SIG[letter]
    # pack: transpose-normalise + pad to kernel multiples (copies!)
    opa = a.T if trans[0] == "T" else a
    opb = b.T if trans[1] == "T" else b
    Mp, Np, Kp = (vmem.round_up(M, bm), vmem.round_up(N, bn),
                  vmem.round_up(K, bk))
    opa = jnp.pad(opa, ((0, Mp - M), (0, Kp - K)))
    opb = jnp.pad(opb, ((0, Kp - K), (0, Np - N)))
    sig = kernelgen.KernelSig(letter, "NN", bm, bn, bk)
    out = kmod.gemm_region(sig, opa, opb, None, alpha=alpha, beta=0.0,
                           interpret=interpret)[:M, :N]
    if c is not None:
        out = out + jnp.asarray(beta, out.dtype) * c
    return out


def traditional_pack_bytes(M: int, N: int, K: int, dtype) -> int:
    """HBM bytes the pack step moves (read+write both panels)."""
    item = jnp.dtype(dtype).itemsize
    letter = kernelgen.blas_letter(dtype)
    bm, bn, bk = _PACK_SIG[letter]
    Mp, Np, Kp = vmem.round_up(M, bm), vmem.round_up(N, bn), vmem.round_up(K, bk)
    return 2 * (Mp * Kp + Kp * Np) * item
