"""Kernel Generator (paper §IV-B): the install-time stage.

The paper auto-generates *hundreds* of assembly microkernels, one per
(size x dtype x transposition), at install time.  Here a "kernel" is a
``pl.pallas_call`` instance specialised on a :class:`KernelSig`; the
generator enumerates the legal signature table (sizes derived from the VMEM
allocator instead of the NEON register file), and ``build_kernel`` lowers a
signature to a callable.  Built kernels are cached by signature — the
install-time stage in a JIT world is a materialised signature table plus a
build cache that examples/benchmarks can warm eagerly (``install()``).

dtype naming follows BLAS/the paper:
  S = float32, D = float64, C = complex64, Z = complex128
(f64/complex run on TPU via interpret-mode validation; see DESIGN.md for
the hardware demotion policy.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import vmem
from repro.core.templates import TRANSPOSITIONS

BLAS_DTYPES = {
    "S": jnp.float32,
    "D": jnp.float64,
    "C": jnp.complex64,
    "Z": jnp.complex128,
}
REAL_OF = {"S": jnp.float32, "D": jnp.float64,
           "C": jnp.float32, "Z": jnp.float64}
IS_COMPLEX = {"S": False, "D": False, "C": True, "Z": True}
# extra dtypes the framework layer uses (not in the paper's BLAS set)
FRAMEWORK_DTYPES = {"H": jnp.bfloat16}


def blas_letter(dtype) -> str:
    d = jnp.dtype(dtype)
    for k, v in {**BLAS_DTYPES, **FRAMEWORK_DTYPES}.items():
        if jnp.dtype(v) == d:
            return k
    raise ValueError(f"unsupported dtype {d}")


@dataclasses.dataclass(frozen=True, order=True)
class KernelSig:
    """Identity of one generated kernel (the paper's TABLE I row entry)."""
    letter: str          # S/D/C/Z/H
    trans: str           # NN/NT/TN/TT
    bm: int
    bn: int
    bk: int

    @property
    def dtype(self):
        return {**BLAS_DTYPES, **FRAMEWORK_DTYPES}[self.letter]

    @property
    def real_dtype(self):
        return REAL_OF.get(self.letter, self.dtype)

    @property
    def complex_(self) -> bool:
        return IS_COMPLEX.get(self.letter, False)

    @property
    def acc_dtype(self):
        return jnp.float64 if self.letter in ("D", "Z") else jnp.float32

    @property
    def name(self) -> str:
        kind = {"S": "sgemm", "D": "dgemm", "C": "cgemm", "Z": "zgemm",
                "H": "hgemm"}[self.letter]
        return f"{kind}_{self.trans.lower()}_{self.bm}x{self.bn}x{self.bk}"

    def footprint(self) -> vmem.Footprint:
        return vmem.footprint(self.bm, self.bn, self.bk, self.real_dtype,
                              complex_=self.complex_,
                              acc_dtype=self.acc_dtype)


# --------------------------------------------------------------------------
# Install-time enumeration.
#
# The paper's table sizes (SGEMM_NN: 16x{1..4}, 12x{1..6}, 8x{1..8},
# 4x{1..13}, ...) fall out of 32 NEON registers.  The TPU table falls out of
# the (sublane, lane) grain and the VMEM budget.  TN gets a reduced table,
# mirroring the paper's observation that TN kernels must be smaller (their
# C-register pressure; for us, the in-VMEM relayout cost of a
# lane-transposed LHS).
# --------------------------------------------------------------------------

_BM_CANDIDATES = (8, 16, 32, 64, 128, 256, 512)
_BN_CANDIDATES = (128, 256, 512)
_BK_CANDIDATES = (128, 256, 512, 1024, 2048)
_TN_BM = (8, 16, 32, 64, 128)
_TN_BN = (128, 256)


@functools.lru_cache(maxsize=None)
def kernel_table(letter: str, trans: str) -> Tuple[KernelSig, ...]:
    """All legal generated kernels for one (dtype, transposition)."""
    if trans not in TRANSPOSITIONS:
        raise ValueError(trans)
    real = REAL_OF.get(letter, FRAMEWORK_DTYPES.get(letter))
    if real is None:
        raise ValueError(letter)
    cx = IS_COMPLEX.get(letter, False)
    bms = _TN_BM if trans == "TN" else _BM_CANDIDATES
    bns = _TN_BN if trans == "TN" else _BN_CANDIDATES
    sub = vmem.sublane(real)
    out: List[KernelSig] = []
    for bm in bms:
        if bm % sub:
            continue
        for bn in bns:
            for bk in _BK_CANDIDATES:
                sig = KernelSig(letter, trans, bm, bn, bk)
                if sig.footprint().fits:
                    # prefer kernels whose accumulator does not spill
                    out.append(sig)
    return tuple(sorted(out))


@functools.lru_cache(maxsize=None)
def full_table() -> Tuple[KernelSig, ...]:
    """The complete install-time kernel census (paper TABLE I analogue)."""
    sigs: List[KernelSig] = []
    for letter in ("S", "D", "C", "Z", "H"):
        for trans in TRANSPOSITIONS:
            sigs.extend(kernel_table(letter, trans))
    return tuple(sigs)


# --------------------------------------------------------------------------
# Build cache: signature -> compiled-callable.
# --------------------------------------------------------------------------

_BUILD_CACHE: Dict[Tuple, Callable] = {}


def build_kernel(sig: KernelSig, *, has_c_in: bool = False,
                 interpret: bool = False) -> Callable:
    """Lower one signature to a callable pallas kernel.

    Returned callable computes ``alpha * op(A) @ op(B) + beta * C`` for
    operand shapes that are any multiple of the block size (the grid is
    derived from the actual shapes at call time); edge cells are handled by
    the in-kernel K-mask + Pallas OOB write semantics, NOT by a packed copy.
    """
    from repro.kernels import iaat_gemm  # deferred: kernels import core
    key = (sig, has_c_in, interpret)
    fn = _BUILD_CACHE.get(key)
    if fn is None:
        fn = iaat_gemm.make_gemm_kernel(sig, has_c_in=has_c_in,
                                        interpret=interpret)
        _BUILD_CACHE[key] = fn
    return fn


def install(letters: Sequence[str] = ("S", "D", "C", "Z"),
            trans: Sequence[str] = TRANSPOSITIONS,
            *, interpret: bool = False,
            max_per_family: Optional[int] = None,
            tune: bool = False,
            tune_kwargs: Optional[dict] = None) -> int:
    """Eagerly build the kernel table (the install-time stage proper).

    Returns the number of kernels built.  ``max_per_family`` trims each
    (dtype, trans) family for quick installs in tests.  With ``tune=True``
    the build is followed by the empirical sweep (repro.tune): measured
    winners are merged into the persistent DeviceProfile and activated,
    so a subsequent ``configure(backend="tuned")`` dispatch uses them —
    this is the full install-time stage the paper describes, generation
    plus selection.  ``tune_kwargs`` forwards to ``repro.tune.search.sweep``
    (defaults are the quick cube sweep so tests stay fast).
    """
    n = 0
    for letter in letters:
        for tr in trans:
            fam = kernel_table(letter, tr)
            if max_per_family is not None:
                fam = fam[:max_per_family]
            for sig in fam:
                build_kernel(sig, interpret=interpret)
                n += 1
    if tune:
        from repro.tune import profile as profile_mod, search
        kw = dict(cube_only=True, max_dim=128, top=2, reps=3,
                  interpret=interpret)
        kw.update(tune_kwargs or {})
        prof = search.sweep(letters, trans, **kw)
        path = profile_mod.default_profile_path(mode=prof.mode)
        try:
            prof = profile_mod.DeviceProfile.load(path).merge(prof)
        except (OSError, ValueError, KeyError):
            pass        # absent or unusable existing profile: overwrite
        prof.save(path)
        profile_mod.set_active_profile(prof)
    return n


def census() -> Dict[str, int]:
    """Kernel counts per (dtype, trans) — the TABLE I shape of our table."""
    out: Dict[str, int] = {}
    for letter in ("S", "D", "C", "Z", "H"):
        for tr in TRANSPOSITIONS:
            out[f"{letter}GEMM_{tr}"] = len(kernel_table(letter, tr))
    return out
