"""Paper TABLE I, verbatim: the ARMv8 generated-kernel size table.

Used by the cost-model benchmarks (tiling memops reproduction, kernel
census) so the run-time tile algorithm can be validated against the paper's
own numbers (Fig. 2: 15x15 SGEMM_NN -> 72K+450 loads vs 105K+450
traditional) independently of the TPU block table.

Encoding: for each (letter, trans), a list of (m, n_max) meaning kernels
m x {1..n_max} exist.  TT families are stored transposed in the paper
({1..n}xM); we normalise to (m, n_max) with ``tt_swapped=True`` semantics
handled by the tiler via orientation flip.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

# (m, n_max) rows; kernels are m x {1..n_max}
ARMV8_TABLE: Dict[Tuple[str, str], Tuple[Tuple[int, int], ...]] = {
    ("S", "NN"): ((16, 4), (12, 6), (8, 8), (4, 13), (3, 13), (2, 13), (1, 13)),
    ("S", "NT"): ((16, 4), (12, 8), (8, 8), (4, 20), (3, 24), (2, 28), (1, 32)),
    ("S", "TN"): ((4, 4), (3, 5), (2, 7), (1, 10)),
    # TT is the NN table mirrored: {1..4}x16 etc.
    ("S", "TT"): ((16, 4), (12, 6), (8, 8), (4, 13), (3, 13), (2, 13), (1, 13)),
    ("D", "NN"): ((8, 4), (4, 8), (3, 8), (2, 15), (1, 15)),
    ("D", "NT"): ((8, 4), (4, 8), (3, 8), (2, 20), (1, 20)),
    ("D", "TN"): ((4, 4), (3, 5), (2, 7), (1, 10)),
    ("D", "TT"): ((8, 4), (4, 8), (3, 8), (2, 15), (1, 15)),
    ("C", "NN"): ((8, 4), (4, 9), (3, 9), (2, 12), (1, 20)),
    ("C", "NT"): ((8, 4), (4, 8), (3, 8), (2, 12), (1, 20)),
    ("C", "TN"): ((4, 9), (3, 9), (2, 12), (1, 20)),
    ("C", "TT"): ((8, 4), (4, 9), (3, 9), (2, 12), (1, 20)),
    ("Z", "NN"): ((4, 4), (3, 4), (2, 7), (1, 10)),
    ("Z", "NT"): ((4, 4), (3, 4), (2, 7), (1, 10)),
    ("Z", "TN"): ((4, 4), (3, 4), (2, 7), (1, 10)),
    ("Z", "TT"): ((4, 4), (3, 4), (2, 7), (1, 10)),
}

# Transpositions whose paper table is column-major (n x m kernels): the
# tiler solves the flipped problem and swaps back.
MIRRORED = {"TT"}


def kernel_sizes(letter: str, trans: str) -> List[Tuple[int, int]]:
    """Explicit (m, n) kernel list for one family."""
    rows = ARMV8_TABLE[(letter, trans)]
    return [(m, n) for m, n_max in rows for n in range(1, n_max + 1)]


def widths_for(letter: str, trans: str) -> Dict[int, int]:
    """m -> n_max mapping (the tiler's feasibility oracle)."""
    return {m: n_max for m, n_max in ARMV8_TABLE[(letter, trans)]}


def census() -> Dict[str, int]:
    """Kernel count per family — the paper's 'hundreds of kernels'."""
    out = {}
    for (letter, trans), rows in ARMV8_TABLE.items():
        out[f"{letter}GEMM_{trans}"] = sum(n for _, n in rows)
    return out


def total_kernels() -> int:
    return sum(census().values())


# Paper-quoted reference points used as benchmark assertions:
PAPER_FIG2_TRADITIONAL_COEFF = 105   # 15x15 SGEMM_NN, traditional tiling
PAPER_FIG2_IAAT_COEFF = 72           # 15x15 SGEMM_NN, IAAT tiling
PAPER_SMALL_THRESHOLD = 80           # cbrt(MNK) bound, non-TN
PAPER_SMALL_THRESHOLD_TN = 32        # cbrt(MNK) bound, TN
