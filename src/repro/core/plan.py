"""Kernel Executing Plan (paper §V-B).

After the input-aware tile algorithm produces a :class:`Tiling`, the plan
builder fuses maximal runs of identical blocks into *regions* (one
``pallas_call`` grid each) and binds every region to a generated kernel
signature from the install-time table.  Executing the plan = running the
region kernels and stitching their outputs — no pack step, no boundary
scalar code.

Plans are cached by the full problem signature, which is the paper's
"repeated same-size GEMM" sweet spot: the first call plans, every
subsequent call (and every jit retrace with the same shapes) reuses the
plan for free.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core import kernelgen, vmem
from repro.core.kernelgen import KernelSig
from repro.core.tiler import Block, Tiling, tile_tpu


@dataclasses.dataclass(frozen=True)
class Region:
    """A (gm x gn) grid of identical (bm x bn) kernel blocks."""
    sig: KernelSig
    m0: int
    n0: int
    gm: int
    gn: int

    @property
    def m_extent(self) -> int:
        return self.gm * self.sig.bm

    @property
    def n_extent(self) -> int:
        return self.gn * self.sig.bn


@dataclasses.dataclass(frozen=True)
class Plan:
    M: int
    N: int
    K: int
    letter: str
    trans: str
    regions: Tuple[Region, ...]
    tiling: Tiling

    @property
    def num_kernel_calls(self) -> int:
        return len(self.regions)

    def memops(self) -> int:
        return self.tiling.memops(self.K)


def _choose_bk(letter: str, trans: str, bm: int, bn: int, K: int) -> int:
    """Largest table bk that fits VMEM with (bm, bn); capped near K."""
    sig0 = kernelgen.kernel_table(letter, trans)
    cands = sorted({s.bk for s in sig0 if s.bm == bm and s.bn == bn})
    if not cands:
        raise ValueError(f"no kernel {letter}/{trans} {bm}x{bn}")
    ka = vmem.align_k(K, kernelgen.REAL_OF.get(letter, jnp.bfloat16))
    # smallest bk covering K in one step, else largest available (more k
    # reuse per C block residency = fewer acc spills).
    for bk in cands:
        if bk >= ka:
            return bk
    return cands[-1]


def _override_plan(M: int, N: int, K: int, letter: str, trans: str,
                   sig: KernelSig) -> Plan:
    """Single-region plan pinned to a tuned kernel signature.

    The empirical tuner (repro.tune) measures whole-problem kernels, so a
    profile override is one ceil-div grid of ``sig`` blocks covering C;
    M/N overhang is resolved by the kernels' masking exactly as in tiled
    plans."""
    if sig.letter != letter or sig.trans != trans:
        raise ValueError(f"override {sig.name} does not match "
                         f"{letter}/{trans}")
    gm = -(M // -sig.bm)
    gn = -(N // -sig.bn)
    blocks = []
    for i in range(gm):
        m0 = i * sig.bm
        for j in range(gn):
            n0 = j * sig.bn
            blocks.append(Block(m0, n0, min(sig.bm, M - m0),
                                min(sig.bn, N - n0)))
    tiling = Tiling(M, N, tuple(blocks), "tuned")
    return Plan(M, N, K, letter, trans,
                (Region(sig, 0, 0, gm, gn),), tiling)


@functools.lru_cache(maxsize=4096)
def build_plan(M: int, N: int, K: int, letter: str, trans: str,
               method: str = "dp",
               override: Optional[KernelSig] = None) -> Plan:
    if override is not None:
        return _override_plan(M, N, K, letter, trans, override)
    tiling = tile_tpu(M, N, letter, trans, method)
    # fuse: per stripe, merge equal-width runs; then merge vertically
    # adjacent stripes with identical runs.
    rows: List[Tuple[int, int, List[Tuple[int, int, int]]]] = []
    by_row: dict = {}
    for b in tiling.blocks:
        by_row.setdefault((b.m0, b.m), []).append(b)
    for (m0, m), blocks in sorted(by_row.items()):
        blocks.sort(key=lambda b: b.n0)
        runs: List[Tuple[int, int, int]] = []  # (n0, n, count)
        for b in blocks:
            if runs and runs[-1][1] == b.n and \
                    runs[-1][0] + runs[-1][1] * runs[-1][2] == b.n0:
                n0, n, c = runs[-1]
                runs[-1] = (n0, n, c + 1)
            else:
                runs.append((b.n0, b.n, 1))
        rows.append((m0, m, runs))
    merged: List[Tuple[int, int, int, List[Tuple[int, int, int]]]] = []
    for m0, m, runs in rows:
        if merged and merged[-1][1] == m and merged[-1][3] == runs \
                and merged[-1][0] + merged[-1][1] * merged[-1][2] == m0:
            p0, pm, pc, pruns = merged[-1]
            merged[-1] = (p0, pm, pc + 1, pruns)
        else:
            merged.append((m0, m, 1, runs))
    regions: List[Region] = []
    for m0, m, gm, runs in merged:
        for n0, n, gn in runs:
            bk = _choose_bk(letter, trans, m, n, K)
            regions.append(Region(KernelSig(letter, trans, m, n, bk),
                                  m0, n0, gm, gn))
    return Plan(M, N, K, letter, trans, tuple(regions), tiling)


# --------------------------------------------------------------------------
# Execution.
# --------------------------------------------------------------------------

def _slice_operand(x, lo: int, hi: int, axis: int):
    idx = [slice(None), slice(None)]
    idx[axis] = slice(lo, hi)
    return x[tuple(idx)]


def execute(plan: Plan, a, b, c=None, alpha=1.0, beta=0.0, *,
            interpret: bool = False):
    """Run the kernel executing plan; returns C (M x N)."""
    from repro.kernels import iaat_gemm
    M, N, K, trans = plan.M, plan.N, plan.K, plan.trans
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    out = jnp.zeros((M, N), out_dtype) if len(plan.regions) > 1 or \
        plan.regions[0].m_extent < M or plan.regions[0].n_extent < N or \
        plan.regions[0].m0 or plan.regions[0].n0 else None
    a_m_axis = 0 if trans[0] == "N" else 1
    b_n_axis = 1 if trans[1] == "N" else 0
    result = None
    for r in plan.regions:
        m_lo, m_hi = r.m0, min(M, r.m0 + r.m_extent)
        n_lo, n_hi = r.n0, min(N, r.n0 + r.n_extent)
        if m_lo >= M or n_lo >= N:
            continue  # fully-overhang region (alignment padding)
        a_sl = _slice_operand(a, m_lo, m_hi, a_m_axis)
        b_sl = _slice_operand(b, n_lo, n_hi, b_n_axis)
        c_sl = None
        if c is not None:
            c_sl = c[m_lo:m_hi, n_lo:n_hi]
        blk = iaat_gemm.gemm_region(r.sig, a_sl, b_sl, c_sl,
                                    alpha=alpha, beta=beta,
                                    interpret=interpret)
        if out is None:
            result = blk
        else:
            out = lax.dynamic_update_slice(out, blk.astype(out_dtype),
                                           (m_lo, n_lo))
    return result if out is None else out
