"""Computational Template Designer (paper §IV-A), adapted to TPU.

The paper abstracts ARMv8 FMA patterns (``sfmlas``/``dfmlav``/``sfcmlas``…)
as templates that the kernel generator stitches into microkernels.  On TPU
the analogous "instruction" is a block-level contraction issued to the MXU
(``lax.dot_general`` with explicit dimension numbers).  Each template below
is a *block* compute pattern:

* ``contract``        — real vector/matrix multiply-accumulate (fmla family),
                        one template per transposition (dimension numbers do
                        the work of the paper's per-transposition load
                        strategies, so no data relayout = no pack step).
* ``cmul_karatsuba``  — complex multiply-accumulate via 3 real contractions
                        (the fcmla analogue; 3-mult Gauss trick chosen by the
                        kernel optimizer over the naive 4-mult form).
* ``cmul_fcmla``      — the literal 4-real-multiplication fcmla pattern,
                        kept for parity with the paper's template table.
* ``epilogue_axpby``  — the alpha/beta update C = alpha*AB + beta*C.

Templates are pure functions of jnp arrays so the same code path serves the
Pallas kernel body (operating on VMEM refs' loaded blocks) and the jnp
reference oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

# Transposition encoding, matching the paper: op(A)@op(B); "N" = as stored,
# "T" = transposed.  Storage convention (row-major):
#   A: (M, K) if A-trans == "N" else (K, M)
#   B: (K, N) if B-trans == "N" else (N, K)
TRANSPOSITIONS = ("NN", "NT", "TN", "TT")

# dot_general dimension numbers for each transposition. Contracting the
# stored arrays directly (no transpose op emitted) is the TPU analogue of
# the paper's "remove the pack step": the MXU consumes either layout.
_DIMS = {
    "NN": (((1,), (0,)), ((), ())),  # (M,K) x (K,N)
    "NT": (((1,), (1,)), ((), ())),  # (M,K) x (N,K)
    "TN": (((0,), (0,)), ((), ())),  # (K,M) x (K,N)
    "TT": (((0,), (1,)), ((), ())),  # (K,M) x (N,K)
}
# Output of TT dot above is (M, N) already because we contract a-dim0/b-dim1
# leaving (M,)+(N,).  For TN the remaining dims are (M,)+(N,) as well.


def contract(a: jax.Array, b: jax.Array, trans: str,
             acc_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Real multiply-accumulate template (sfmlas/dfmlas family).

    Returns op(a) @ op(b) accumulated in ``acc_dtype`` (MXU native f32
    accumulation; f64 in interpret mode for DGEMM/ZGEMM parity).
    """
    if trans not in _DIMS:
        raise ValueError(f"bad transposition {trans!r}")
    return lax.dot_general(a, b, _DIMS[trans],
                           preferred_element_type=acc_dtype)


def contract_flops(m: int, n: int, k: int, complex_: bool = False,
                   karatsuba: bool = True) -> int:
    """FLOPs of one block contraction (for the cost model / roofline)."""
    real = 2 * m * n * k
    if not complex_:
        return real
    return (3 if karatsuba else 4) * real + 5 * m * n


def cmul_karatsuba(ar, ai, br, bi, trans: str, acc_dtype=jnp.float32):
    """Complex MMA via 3 real contractions (Gauss/Karatsuba).

    P1 = Ar*Br ; P2 = Ai*Bi ; P3 = (Ar+Ai)(Br+Bi)
    Cr = P1 - P2 ; Ci = P3 - P1 - P2
    Returns the three partial products so a k-looped kernel can accumulate
    each independently (the partials are linear in A,B so per-k-step
    accumulation commutes with the final combine).
    """
    p1 = contract(ar, br, trans, acc_dtype)
    p2 = contract(ai, bi, trans, acc_dtype)
    p3 = contract(ar + ai, br + bi, trans, acc_dtype)
    return p1, p2, p3


def karatsuba_combine(p1, p2, p3) -> Tuple[jax.Array, jax.Array]:
    return p1 - p2, p3 - p1 - p2


def cmul_fcmla(ar, ai, br, bi, trans: str, acc_dtype=jnp.float32):
    """The paper's fcmla pattern: 4 real contractions (naive complex MMA).

    Kept for template-table parity and as the cost-model baseline the
    kernel optimizer improves upon (3-mult Karatsuba).
    """
    cr = contract(ar, br, trans, acc_dtype) - contract(ai, bi, trans, acc_dtype)
    ci = contract(ar, bi, trans, acc_dtype) + contract(ai, br, trans, acc_dtype)
    return cr, ci


def epilogue_axpby(acc, c_old, alpha, beta, out_dtype):
    """C = alpha*acc + beta*C template (GEMM epilogue, fused in-kernel)."""
    out = alpha * acc
    if c_old is not None:
        out = out + beta * c_old.astype(acc.dtype)
    return out.astype(out_dtype)


def negv(x):
    """fneg template (used by the complex combine in the fcmla path)."""
    return -x


@dataclasses.dataclass(frozen=True)
class TemplateInfo:
    """Census entry for the template table (benchmarks/kernel_table.py)."""
    name: str
    arity: int
    description: str


TEMPLATE_TABLE = (
    TemplateInfo("contract.NN", 2, "real MMA, A row-major, B row-major"),
    TemplateInfo("contract.NT", 2, "real MMA, B stored transposed"),
    TemplateInfo("contract.TN", 2, "real MMA, A stored transposed"),
    TemplateInfo("contract.TT", 2, "real MMA, both stored transposed"),
    TemplateInfo("cmul_karatsuba", 4, "complex MMA, 3 real contractions"),
    TemplateInfo("cmul_fcmla", 4, "complex MMA, 4 real contractions (paper)"),
    TemplateInfo("epilogue_axpby", 2, "alpha/beta epilogue"),
    TemplateInfo("negv", 1, "negation (fneg)"),
)
