"""The Input-Aware Adaptive Tile Algorithm (paper §V-A) — the run-time stage.

Given (M, N, K, dtype, transposition) and the install-time kernel table,
tile matrix C into blocks such that every block is EXACTLY a generated
kernel size (zero boundary processing) while minimizing the paper's memops
objective  Σᵢ(mᵢ+nᵢ)·K + 2MN  (principle b), preferring big SIMD-aligned
blocks (principles a, c).

Two planners are provided:

* ``greedy`` — faithful to the paper's Algorithm 2 (TileSingleDim greedy
  with the remainder-averaging rule, the M≤8/==9/<12/==12/>12 case split
  for SGEMM_NN, and the ExtendTo8/ExtendTo16 comparison).
* ``dp`` — our beyond-paper planner: exact dynamic programming over row
  stripes.  For a stripe of height m covering N with J blocks the cost is
  m·J + N, so  total = Σ_s m_s·J(m_s) + N·S  is minimised exactly.
  On the paper's own Fig. 2 example (15×15 SGEMM_NN) ``dp`` finds the
  coefficient 72 the paper reports for IAAT (12×{6,6,3} + 3×{13,2}).

The same machinery runs against two kernel tables: the verbatim ARMv8
TABLE I (cost-model benchmarks) and the TPU/VMEM table from ``kernelgen``
(real execution), in which case dims are pre-aligned to the (sublane, lane)
grain and edge overhang is handled by the kernels' masking, not by 1-wide
cleanup kernels.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import cost, kernelgen, paper_table, vmem


@dataclasses.dataclass(frozen=True)
class Block:
    m0: int
    n0: int
    m: int
    n: int


@dataclasses.dataclass(frozen=True)
class Tiling:
    M: int
    N: int
    blocks: Tuple[Block, ...]
    method: str

    @property
    def coeff(self) -> int:
        return cost.memops_coeff((b.m, b.n) for b in self.blocks)

    def memops(self, K: int) -> int:
        return cost.memops_blocks(((b.m, b.n) for b in self.blocks),
                                  K, self.M, self.N)

    def validate_cover(self) -> None:
        """Invariant: blocks exactly partition the (M, N) rectangle."""
        covered = sum(b.m * b.n for b in self.blocks)
        assert covered == self.M * self.N, (covered, self.M * self.N)
        rects = sorted((b.m0, b.n0, b.m, b.n) for b in self.blocks)
        for i, (r0, c0, rm, rn) in enumerate(rects):
            assert 0 <= r0 and r0 + rm <= self.M
            assert 0 <= c0 and c0 + rn <= self.N
            for (s0, d0, sm, sn) in rects[i + 1:]:
                if r0 < s0 + sm and s0 < r0 + rm \
                        and c0 < d0 + sn and d0 < c0 + rn:
                    raise AssertionError(f"overlap {rects[i]} vs {(s0,d0,sm,sn)}")


# --------------------------------------------------------------------------
# Kernel-table views.
# --------------------------------------------------------------------------

class TableView:
    """m -> allowed widths, for either the ARMv8 or the TPU table."""

    def __init__(self, widths: Dict[int, Sequence[int]]):
        self._w = {m: tuple(sorted(ws)) for m, ws in widths.items() if ws}

    def heights(self) -> Tuple[int, ...]:
        return tuple(sorted(self._w))

    def widths_for(self, m: int) -> Tuple[int, ...]:
        return self._w.get(m, ())

    @classmethod
    def armv8(cls, letter: str, trans: str) -> "TableView":
        return cls({m: range(1, nmax + 1)
                    for m, nmax in paper_table.widths_for(letter, trans).items()})

    @classmethod
    def tpu(cls, letter: str, trans: str) -> "TableView":
        widths: Dict[int, set] = {}
        for sig in kernelgen.kernel_table(letter, trans):
            widths.setdefault(sig.bm, set()).add(sig.bn)
        return cls({m: sorted(ws) for m, ws in widths.items()})


# --------------------------------------------------------------------------
# TileSingleDim (paper, line 10 of Algorithm 2) + remainder averaging.
# --------------------------------------------------------------------------

def tile_single_dim(L: int, sizes: Sequence[int]) -> List[Tuple[int, int]]:
    """Greedy cover of L with ``sizes``; returns [(dim, count)].

    Biggest-first; if the final remainder is 'too small' (< half the
    previous size) the last two pieces are averaged (paper §V-A)."""
    sizes = sorted(set(sizes), reverse=True)
    out: List[Tuple[int, int]] = []
    rest = L
    big = sizes[0]
    if rest >= big:
        cnt = rest // big
        rem = rest - cnt * big
        if 0 < rem < max(1, big // 2) and cnt >= 1:
            # averaging rule: split (big + rem) across two near-equal pieces
            cnt -= 1
            pair = big + rem
            a, b = -(-pair // 2), pair // 2
            a = _snap_down(a, sizes)
            b = pair - a
            if cnt:
                out.append((big, cnt))
            for piece in _split_piece(a, sizes) + _split_piece(b, sizes):
                out.append(piece)
            return _merge_runs(out)
        if cnt:
            out.append((big, cnt))
        rest = rem
    while rest > 0:
        fit = next((s for s in sizes if s <= rest), None)
        if fit is None:
            raise ValueError(f"cannot tile {L} with {sizes}")
        cnt = rest // fit
        out.append((fit, cnt))
        rest -= fit * cnt
    return _merge_runs(out)


def _snap_down(x: int, sizes: Sequence[int]) -> int:
    for s in sorted(sizes, reverse=True):
        if s <= x:
            return s
    return min(sizes)


def _split_piece(p: int, sizes: Sequence[int]) -> List[Tuple[int, int]]:
    out = []
    rest = p
    for s in sorted(sizes, reverse=True):
        if rest <= 0:
            break
        c = rest // s
        if c:
            out.append((s, c))
            rest -= s * c
    if rest:
        raise ValueError(f"cannot split {p} with {sizes}")
    return out


def _merge_runs(runs: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    merged: List[Tuple[int, int]] = []
    for d, c in runs:
        if merged and merged[-1][0] == d:
            merged[-1] = (d, merged[-1][1] + c)
        else:
            merged.append((d, c))
    return merged


# --------------------------------------------------------------------------
# Exact cover DP (our planner).
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _min_cover(N: int, widths: Tuple[int, ...]) -> Optional[Tuple[int, ...]]:
    """Minimal-count exact cover of N by ``widths`` (DP, scaled by gcd)."""
    g = math.gcd(N, functools.reduce(math.gcd, widths))
    n, ws = N // g, tuple(w // g for w in widths)
    INF = 1 << 30
    best = [0] + [INF] * n
    pick = [0] * (n + 1)
    for i in range(1, n + 1):
        for w in ws:
            if w <= i and best[i - w] + 1 < best[i]:
                best[i] = best[i - w] + 1
                pick[i] = w
    if best[n] >= INF:
        return None
    out = []
    i = n
    while i:
        out.append(pick[i] * g)
        i -= pick[i]
    return tuple(sorted(out, reverse=True))


def _stripe_dp(M: int, N: int, table: TableView) -> List[Tuple[int, Tuple[int, ...]]]:
    """Exact DP over stripe heights. Returns [(height, col widths)]."""
    heights = table.heights()
    g = functools.reduce(math.gcd, heights + (M,))
    INF = float("inf")
    # per-height column cover cost: m*J(m) + N
    stripe_cost: Dict[int, Tuple[float, Tuple[int, ...]]] = {}
    for m in heights:
        covN = _min_cover(N, table.widths_for(m))
        if covN is None:
            continue
        stripe_cost[m] = (m * len(covN) + N, covN)
    if not stripe_cost:
        raise ValueError(f"no feasible stripe for N={N}")
    mm = M // g
    best = [0.0] + [INF] * mm
    pick = [0] * (mm + 1)
    hs = sorted(stripe_cost, reverse=True)
    for i in range(1, mm + 1):
        for m in hs:
            ms = m // g
            if m % g == 0 and ms <= i:
                c = best[i - ms] + stripe_cost[m][0]
                if c < best[i]:
                    best[i] = c
                    pick[i] = m
    if best[mm] is INF:
        raise ValueError(f"cannot tile M={M} with heights {heights}")
    stripes = []
    i = mm
    while i:
        m = pick[i]
        stripes.append((m, stripe_cost[m][1]))
        i -= m // g
    stripes.sort(key=lambda s: -s[0])
    return stripes


def _blocks_from_stripes(stripes: List[Tuple[int, Sequence[int]]],
                         M: int, N: int, method: str) -> Tiling:
    blocks: List[Block] = []
    r = 0
    for m, widths in stripes:
        c = 0
        for w in widths:
            blocks.append(Block(r, c, m, w))
            c += w
        assert c == N, (c, N)
        r += m
    assert r == M, (r, M)
    return Tiling(M, N, tuple(blocks), method)


# --------------------------------------------------------------------------
# Paper Algorithm 2 (greedy), generalised from the SGEMM_NN pseudocode.
# --------------------------------------------------------------------------

def _greedy_stripes(M: int, N: int, table: TableView) \
        -> List[Tuple[int, Tuple[int, ...]]]:
    heights = sorted(table.heights(), reverse=True)
    max_n_of = {m: max(table.widths_for(m)) for m in table.heights()}
    # Paper line 1: if N fits the widest kernel of some height, pin n_c = N
    # and take the tallest such height (bigger-block principle).
    pin = [m for m in heights if N <= max_n_of[m]]
    stripes: List[Tuple[int, Tuple[int, ...]]] = []
    if pin:
        m1 = pin[0]
        cnt = M // m1
        rem = M - cnt * m1
        if cnt:
            stripes += [(m1, (N,))] * cnt
        if rem:
            for m, c in tile_single_dim(rem, [h for h in heights if h <= rem] or heights[-1:]):
                cov = _min_cover(N, table.widths_for(m))
                stripes += [(m, cov)] * c
        return stripes
    # Otherwise tile M greedily, then cover N per stripe height greedily.
    for m, c in tile_single_dim(M, heights):
        ws = table.widths_for(m)
        runs = tile_single_dim(N, ws)
        cov = tuple(w for w, cc in runs for _ in range(cc))
        stripes += [(m, cov)] * c
    return stripes


def _greedy_nn_paper(M: int, N: int, table: TableView) \
        -> List[Tuple[int, Tuple[int, ...]]]:
    """Algorithm 2 verbatim for the ARMv8 SGEMM_NN table."""
    W = {m: max(table.widths_for(m)) for m in table.heights()}
    if N <= 13:
        return _greedy_stripes(M, N, table)
    stripes: List[Tuple[int, Tuple[int, ...]]] = []

    def ncov(m, lim):
        runs = tile_single_dim(N, list(range(1, lim + 1)))
        return tuple(w for w, c in runs for _ in range(c))

    if M <= 8:
        for m, c in tile_single_dim(M, [1, 2, 3, 4]):
            stripes += [(m, ncov(m, 13))] * c
    elif M == 9:
        for m in (4, 3, 2):
            stripes.append((m, ncov(m, 13)))
    elif M < 12:
        stripes.append((8, ncov(8, 8)))
        stripes.append((M - 8, ncov(M - 8, 13)))
    elif M == 12:
        stripes.append((12, ncov(12, 6)))
    else:
        q, r = divmod(M, 4)
        if r == 1:
            m1 = [(4, q - 1)]
            m2 = [(3, ncov(3, 8)), (2, ncov(2, 13))]
        else:
            m1 = [(4, q)]
            m2 = [(r, ncov(r, 13))] if r else []
        # ExtendTo8 / ExtendTo16: fuse pairs/quads of 4-stripes into 8/16
        # stripes and keep whichever needs fewer loads.
        cands = []
        for unit in (8, 16):
            n4 = m1[0][1] * 4
            big, rest = divmod(n4, unit)
            st = [(unit, ncov(unit, W.get(unit, 4)))] * big
            if rest:
                for mm, cc in tile_single_dim(rest, [4, 3, 2, 1]):
                    st += [(mm, ncov(mm, 13 if mm <= 4 else 8))] * cc
            cands.append(st)
        best = min(cands, key=lambda st: sum(m * len(ws) for m, ws in st))
        stripes += best
        stripes += [(m, ws) for m, ws in m2]
    return stripes


def tile(M: int, N: int, table: TableView, method: str = "dp",
         paper_nn: bool = False) -> Tiling:
    if method == "dp":
        stripes = _stripe_dp(M, N, table)
    elif method == "greedy":
        stripes = (_greedy_nn_paper if paper_nn else _greedy_stripes)(M, N, table)
        stripes = [(m, tuple(ws)) for m, ws in stripes]
    else:
        raise ValueError(method)
    t = _blocks_from_stripes(stripes, M, N, method)
    t.validate_cover()
    return t


# --------------------------------------------------------------------------
# Public entry points.
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def tile_armv8(M: int, N: int, letter: str = "S", trans: str = "NN",
               method: str = "dp") -> Tiling:
    """Cost-model tiling against the verbatim paper table."""
    if trans in paper_table.MIRRORED:
        t = tile(N, M, TableView.armv8(letter, trans), method,
                 paper_nn=(letter, trans) == ("S", "NN") and method == "greedy")
        blocks = tuple(Block(b.n0, b.m0, b.n, b.m) for b in t.blocks)
        return Tiling(M, N, blocks, method)
    return tile(M, N, TableView.armv8(letter, trans), method,
                paper_nn=(letter, trans) == ("S", "NN") and method == "greedy")


@functools.lru_cache(maxsize=4096)
def tile_tpu(M: int, N: int, letter: str, trans: str,
             method: str = "dp") -> Tiling:
    """Execution tiling against the TPU/VMEM kernel table.

    Dims are aligned up to the dtype grain first; the overhang inside the
    final blocks is resolved by kernel masking (never by scalar cleanup).
    """
    sig0 = kernelgen.kernel_table(letter, trans)
    if not sig0:
        raise ValueError(f"empty kernel table for {letter} {trans}")
    dt = sig0[0].real_dtype
    Ma = vmem.align_m(M, dt)
    Na = vmem.align_n(N, dt)
    return tile(Ma, Na, TableView.tpu(letter, trans), method)
