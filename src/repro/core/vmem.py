"""Register Allocator (paper §IV-C), adapted: the VMEM block allocator.

The paper allocates 32x128-bit NEON registers across three groups (two
ping-pang columns of A_c, two rows of B_c, the whole C_c block).  On TPU the
scarce resource one level up from registers is VMEM (~16 MiB/core); Mosaic
owns actual vector registers.  This module answers the same two questions
the paper's allocator answers:

1. *Does a candidate kernel size fit?*  — ``fits_vmem`` computes the VMEM
   footprint of (double-buffered A block) + (double-buffered B block) +
   (f32 accumulator block) (+ complex plane multipliers) against the budget.
2. *What sizes are legal?* — ``align_*`` snap block dims to the TPU tiling
   grain (sublane x lane, dtype dependent), the analogue of "divisible by
   the length of SIMD register" (paper §V-A principle c).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

LANE = 128          # last-dim tiling grain (all dtypes)
VMEM_BYTES = 16 * 1024 * 1024   # v5e VMEM per core
VMEM_BUDGET = int(VMEM_BYTES * 0.75)  # leave headroom for Mosaic spills/semaphores
PING_PANG = 2       # double buffering multiplier (paper's M1/M2 stages)

# second-to-last dim tiling grain per element width
_SUBLANE = {4: 8, 2: 16, 1: 32, 8: 8}


def sublane(dtype) -> int:
    return _SUBLANE[jnp.dtype(dtype).itemsize]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def align_m(m: int, dtype) -> int:
    return round_up(max(m, 1), sublane(dtype))


def align_n(n: int, dtype) -> int:
    return round_up(max(n, 1), LANE)


def align_k(k: int, dtype) -> int:
    # K appears as the lane dim of A(N)/B(T) and the sublane dim of
    # A(T)/B(N); align to LANE so both layouts are tile-exact.
    return round_up(max(k, 1), LANE)


@dataclasses.dataclass(frozen=True)
class Footprint:
    a_bytes: int
    b_bytes: int
    acc_bytes: int
    c_bytes: int
    total: int

    @property
    def fits(self) -> bool:
        return self.total <= VMEM_BUDGET


def footprint(bm: int, bn: int, bk: int, dtype, *, complex_: bool = False,
              has_c_in: bool = False, acc_dtype=jnp.float32) -> Footprint:
    """VMEM bytes for one grid step of a (bm,bn,bk) GEMM kernel.

    Mirrors the paper's three register groups:
      A group: bm*bk  (x2 ping-pang, x2 planes if complex)
      B group: bk*bn  (x2 ping-pang, x2 planes if complex)
      C group: bm*bn accumulator (f32/f64; x3 planes if complex-karatsuba)
               plus the C input block when beta != 0.
    """
    itemsize = jnp.dtype(dtype).itemsize
    planes = 2 if complex_ else 1
    acc_planes = 3 if complex_ else 1     # karatsuba partials
    acc_item = jnp.dtype(acc_dtype).itemsize
    a = bm * bk * itemsize * PING_PANG * planes
    b = bk * bn * itemsize * PING_PANG * planes
    acc = bm * bn * acc_item * acc_planes
    c = bm * bn * itemsize * planes * (2 if has_c_in else 1)
    return Footprint(a, b, acc, c, a + b + acc + c)


def fits_vmem(bm: int, bn: int, bk: int, dtype, **kw) -> bool:
    return footprint(bm, bn, bk, dtype, **kw).fits


def max_whole_problem(dtype, *, complex_: bool = False) -> int:
    """Largest cube edge s.t. the whole GEMM fits in VMEM in one grid step.

    This is the TPU analogue of the paper's small-GEMM regime: when the
    entire problem is VMEM-resident there is no HBM re-streaming at all
    (the strongest form of "no pack step, no boundary processing").
    """
    lo, hi = 1, 4096
    while lo < hi:
        mid = (lo + hi + 1) // 2
        m = align_m(mid, dtype)
        n = align_n(mid, dtype)
        k = align_k(mid, dtype)
        if fits_vmem(m, n, k, dtype, complex_=complex_):
            lo = mid
        else:
            hi = mid - 1
    return lo


def arithmetic_intensity(bm: int, bn: int, bk: int, dtype,
                         complex_: bool = False) -> float:
    """FLOPs per HBM byte for one kernel block (roofline napkin math)."""
    itemsize = jnp.dtype(dtype).itemsize
    planes = 2 if complex_ else 1
    mults = 3 if complex_ else 1
    flops = 2 * bm * bn * bk * mults
    bytes_ = (bm * bk + bk * bn + bm * bn) * itemsize * planes
    return flops / bytes_


def vreg_pressure(bm: int, bn: int, dtype) -> int:
    """Estimated VREG count for the C accumulator (advisory only: Mosaic
    allocates registers, but kernels whose C block exceeds the physical
    64x(8x128) VREG file will spill to VMEM — the generator uses this to
    order candidates, mirroring the paper's C-group register constraint)."""
    per_vreg = 8 * 128
    return math.ceil((bm * bn) / per_vreg)
