"""Tiled online-softmax attention (Pallas TPU), GQA + causal + sliding
window.

The IAAT connection: prefill attention at 32k+ is a cascade of
(bq x D) @ (D x bk) and (bq x bk) @ (bk x D) block GEMMs; the block sizes
are drawn from the same VMEM-allocator reasoning as the GEMM kernel table
(the flash working set q/k/v/acc/m/l must fit the budget with the
double-buffered pipeline).  Sliding-window blocks that cannot contribute
are skipped entirely (the boundary-processing-removal principle applied to
the attention mask).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _cdiv(a: int, b: int) -> int:
    return -(a // -b)


def _body(bq: int, bkv: int, Sq: int, Sk: int, q_offset: int,
          causal: bool, window: Optional[int], scale: float, nk: int,
          q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq + q_offset
    k_start = j * bkv
    # block-level skip predicates (no work for fully-masked blocks)
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window is not None:
        live &= k_start + bkv - 1 > q_start - window

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        # zero the Sk overhang of v: OOB-padded rows may be garbage/NaN and
        # 0-prob x NaN would poison the accumulator (cf. iaat_gemm K mask)
        krow = k_start + lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(krow < Sk, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        ki = k_start + lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        ok = ki < Sk
        if causal:
            ok &= ki <= qi
        if window is not None:
            ok &= ki > qi - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, scale: Optional[float] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); returns (B, Hq, Sq, D).

    GQA via the kv BlockSpec index map (no repeat-materialisation of kv)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"GQA needs Hq % Hkv == 0, got {Hq}/{Hkv}")
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    nq, nk = _cdiv(Sq, bq), _cdiv(Sk, bkv)
    body = functools.partial(_body, bq, bkv, Sq, Sk, q_offset, causal,
                             window, scale, nk)
    return pl.pallas_call(
        body,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
