"""Grouped / batched small-GEMM kernels (Pallas TPU) — IAAT's ML habitat.

The paper motivates small GEMM with ML workloads; on TPU the dominant such
workload is MoE expert compute: G independent (tokens_g x K) @ (K x N)
products with small, *input-dependent* tokens_g.  Two kernels:

* ``batched_gemm``   — equal-capacity groups (the capacity-routed MoE
  layout): x (G, C, K) @ w (G, K, N).  Grid (G, gm, gn, gk); block sizes
  come from the IAAT kernel table for the (C, N, K) small-GEMM problem.
* ``ragged_gemm``    — group-contiguous rows with traced group sizes,
  group->tile mapping delivered through scalar prefetch (SMEM), the
  run-time-stage analogue for dropless MoE.  Rows must be padded per group
  to a multiple of the row-block (the dispatcher does this); padded rows
  are zero so they contribute nothing.

Block selection flows through ``repro.api`` (one Router for every GEMM
shape): a measured DeviceProfile entry for the per-group problem wins
under ``Policy(backend="tuned")``, and :func:`pick_blocks` below is the
analytical fallback the router uses for unmeasured classes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import kernelgen, vmem


def _cdiv(a: int, b: int) -> int:
    return -(a // -b)


def pick_blocks(C: int, K: int, N: int, dtype) -> tuple:
    """IAAT install-time table lookup for the per-group problem."""
    letter = kernelgen.blas_letter(dtype)
    table = kernelgen.kernel_table(letter, "NN")
    bm_c = [s.bm for s in table]
    bn_c = [s.bn for s in table]
    bk_c = [s.bk for s in table]
    bm = max([b for b in bm_c if b <= vmem.align_m(C, dtype)] or [min(bm_c)])
    bn = max([b for b in bn_c if b <= vmem.align_n(N, dtype)] or [min(bn_c)])
    bk = max([b for b in bk_c if b <= vmem.align_k(K, dtype)] or [min(bk_c)])
    while not vmem.fits_vmem(bm, bn, bk, dtype):
        bk = max(bk // 2, 128)
        if bk == 128 and not vmem.fits_vmem(bm, bn, bk, dtype):
            bn = max(bn // 2, 128)
            if bn == 128:
                bm = max(bm // 2, vmem.sublane(dtype))
                if bm == vmem.sublane(dtype):
                    break
    return bm, bn, bk


# --------------------------------------------------------------------------
# batched (equal-capacity) grouped GEMM
# --------------------------------------------------------------------------

def _batched_body(nk: int, K: int, bk: int, *refs):
    x_ref, w_ref, o_ref, acc_ref = refs
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]
    w = w_ref[0]
    if K % bk:
        kid = lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(kid + k * bk < K, x, 0)
        kid = lax.broadcasted_iota(jnp.int32, w.shape, 0)
        w = jnp.where(kid + k * bk < K, w, 0)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def batched_gemm(x: jax.Array, w: jax.Array, *, interpret: bool = True,
                 blocks: Optional[tuple] = None) -> jax.Array:
    """x: (G, C, K), w: (G, K, N) -> (G, C, N)."""
    G, C, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = blocks or pick_blocks(C, K, N, x.dtype)
    gm, gn, nk = _cdiv(C, bm), _cdiv(N, bn), _cdiv(K, bk)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    return pl.pallas_call(
        functools.partial(_batched_body, nk, K, bk),
        grid=(G, gm, gn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, C, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


# --------------------------------------------------------------------------
# ragged grouped GEMM (scalar-prefetched group ids)
# --------------------------------------------------------------------------

def _ragged_body(nk: int, K: int, bk: int, gid_ref, *refs):
    x_ref, w_ref, o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[0]
    if K % bk:
        kid = lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(kid + k * bk < K, x, 0)
        kid = lax.broadcasted_iota(jnp.int32, w.shape, 0)
        w = jnp.where(kid + k * bk < K, w, 0)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def ragged_gemm(x: jax.Array, w: jax.Array, tile_group_ids: jax.Array,
                *, bm: int = 128, interpret: bool = True,
                blocks: Optional[tuple] = None) -> jax.Array:
    """x: (T, K) group-contiguous (each group padded to bm rows, padding
    zeroed); w: (G, K, N); tile_group_ids: (T//bm,) int32 mapping each row
    tile to its expert.  Returns (T, N)."""
    T, K = x.shape
    G, _, N = w.shape
    if T % bm:
        raise ValueError(f"T={T} must be padded to bm={bm}")
    _, bn, bk = blocks or pick_blocks(bm, K, N, x.dtype)
    gm, gn, nk = T // bm, _cdiv(N, bn), _cdiv(K, bk)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(gm, gn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, gids: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, gids: (gids[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, gids: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_ragged_body, nk, K, bk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, N), out_dtype),
        interpret=interpret,
    )(tile_group_ids.astype(jnp.int32), x, w)
