"""The generated IAAT GEMM microkernel family (Pallas TPU).

One ``pl.pallas_call`` instance per :class:`KernelSig` — the TPU analogue
of the paper's auto-generated assembly kernels:

* operands are consumed in their native (possibly transposed) layout via
  per-transposition BlockSpec index maps + dot_general dimension numbers
  (templates.py) — **no pack step**;
* the K tail is masked in-kernel with an iota predicate and M/N overhang
  is resolved by Pallas's out-of-bounds write clipping — **no scalar
  boundary code**;
* accumulation lives in a VMEM scratch across the (arbitrary) K grid
  dimension, and HBM->VMEM block streaming is double-buffered by the
  Pallas pipeline — the ping-pang operation (paper §IV-B) realised by the
  Mosaic software pipeline instead of hand-interleaved loads;
* complex kernels take/return separate real/imag planes and use the
  3-multiplication Karatsuba template (kernel-optimizer choice; the
  paper's 4-mult fcmla template is kept in templates.py as the baseline).

alpha/beta are baked statically per built kernel (the paper's kernels are
likewise specialised; the dispatch layer falls back to an out-of-kernel
epilogue for traced alpha/beta).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import templates
from repro.core.kernelgen import KernelSig


def _cdiv(a: int, b: int) -> int:
    return -(a // -b)


def _compiler_params():
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        try:
            return pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except Exception:
            return None


def _a_spec(sig: KernelSig):
    if sig.trans[0] == "N":   # A stored (M, K)
        return pl.BlockSpec((sig.bm, sig.bk), lambda i, j, k: (i, k))
    return pl.BlockSpec((sig.bk, sig.bm), lambda i, j, k: (k, i))


def _b_spec(sig: KernelSig):
    if sig.trans[1] == "N":   # B stored (K, N)
        return pl.BlockSpec((sig.bk, sig.bn), lambda i, j, k: (k, j))
    return pl.BlockSpec((sig.bn, sig.bk), lambda i, j, k: (j, k))


def _c_spec(sig: KernelSig):
    return pl.BlockSpec((sig.bm, sig.bn), lambda i, j, k: (i, j))


def _k_axis(trans_char: str, operand: str) -> int:
    # axis of K in the stored block
    if operand == "a":
        return 1 if trans_char == "N" else 0
    return 0 if trans_char == "N" else 1


def _mask_k(x, k_id, bk: int, K: int, axis: int):
    """Zero the K-overhang of a block (guards OOB garbage, incl. NaN/inf)."""
    idx = lax.broadcasted_iota(jnp.int32, x.shape, axis)
    return jnp.where(idx + k_id * bk < K, x, jnp.zeros_like(x))


# --------------------------------------------------------------------------
# Real kernel.
# --------------------------------------------------------------------------

def _real_body(sig: KernelSig, nk: int, K: int, alpha, beta, has_c: bool,
               out_dtype, *refs):
    if has_c:
        a_ref, b_ref, c_ref, o_ref, acc_ref = refs
    else:
        a_ref, b_ref, o_ref, acc_ref = refs
        c_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if K % sig.bk:
        a = _mask_k(a, k, sig.bk, K, _k_axis(sig.trans[0], "a"))
        b = _mask_k(b, k, sig.bk, K, _k_axis(sig.trans[1], "b"))
    acc_ref[...] += templates.contract(a, b, sig.trans, sig.acc_dtype)

    @pl.when(k == nk - 1)
    def _fin():
        acc = acc_ref[...]
        c_old = c_ref[...] if c_ref is not None else None
        o_ref[...] = templates.epilogue_axpby(acc, c_old, alpha, beta,
                                              out_dtype)


def _real_call(sig: KernelSig, a, b, c, alpha, beta, interpret: bool):
    trans = sig.trans
    M = a.shape[0] if trans[0] == "N" else a.shape[1]
    N = b.shape[1] if trans[1] == "N" else b.shape[0]
    K = a.shape[1] if trans[0] == "N" else a.shape[0]
    gm, gn, nk = _cdiv(M, sig.bm), _cdiv(N, sig.bn), _cdiv(K, sig.bk)
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    has_c = c is not None
    in_specs = [_a_spec(sig), _b_spec(sig)]
    args = [a, b]
    if has_c:
        in_specs.append(_c_spec(sig))
        args.append(c)
    kernel = functools.partial(_real_body, sig, nk, K, alpha, beta, has_c,
                               out_dtype)
    kw = {}
    if not interpret:
        cp = _compiler_params()
        if cp is not None:
            kw["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, nk),
        in_specs=in_specs,
        out_specs=_c_spec(sig),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((sig.bm, sig.bn), sig.acc_dtype)],
        interpret=interpret,
        **kw,
    )(*args)


# --------------------------------------------------------------------------
# Complex kernel (plane-split, Karatsuba accumulation).
# --------------------------------------------------------------------------

def _cx_body(sig: KernelSig, nk: int, K: int, alpha, beta, has_c: bool,
             out_dtype, *refs):
    if has_c:
        (ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref,
         or_ref, oi_ref, p1_ref, p2_ref, p3_ref) = refs
    else:
        (ar_ref, ai_ref, br_ref, bi_ref,
         or_ref, oi_ref, p1_ref, p2_ref, p3_ref) = refs
        cr_ref = ci_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        p1_ref[...] = jnp.zeros_like(p1_ref)
        p2_ref[...] = jnp.zeros_like(p2_ref)
        p3_ref[...] = jnp.zeros_like(p3_ref)

    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    if K % sig.bk:
        ka = _k_axis(sig.trans[0], "a")
        kb = _k_axis(sig.trans[1], "b")
        ar = _mask_k(ar, k, sig.bk, K, ka)
        ai = _mask_k(ai, k, sig.bk, K, ka)
        br = _mask_k(br, k, sig.bk, K, kb)
        bi = _mask_k(bi, k, sig.bk, K, kb)
    p1, p2, p3 = templates.cmul_karatsuba(ar, ai, br, bi, sig.trans,
                                          sig.acc_dtype)
    p1_ref[...] += p1
    p2_ref[...] += p2
    p3_ref[...] += p3

    @pl.when(k == nk - 1)
    def _fin():
        cr_acc, ci_acc = templates.karatsuba_combine(
            p1_ref[...], p2_ref[...], p3_ref[...])
        ar_, ai_ = float(alpha.real), float(alpha.imag)
        outr = ar_ * cr_acc - ai_ * ci_acc
        outi = ar_ * ci_acc + ai_ * cr_acc
        if cr_ref is not None:
            br_, bi_ = float(beta.real), float(beta.imag)
            co_r = cr_ref[...].astype(cr_acc.dtype)
            co_i = ci_ref[...].astype(cr_acc.dtype)
            outr += br_ * co_r - bi_ * co_i
            outi += br_ * co_i + bi_ * co_r
        or_ref[...] = outr.astype(out_dtype)
        oi_ref[...] = outi.astype(out_dtype)


def _cx_call(sig: KernelSig, a, b, c, alpha, beta, interpret: bool):
    trans = sig.trans
    M = a.shape[0] if trans[0] == "N" else a.shape[1]
    N = b.shape[1] if trans[1] == "N" else b.shape[0]
    K = a.shape[1] if trans[0] == "N" else a.shape[0]
    gm, gn, nk = _cdiv(M, sig.bm), _cdiv(N, sig.bn), _cdiv(K, sig.bk)
    real_dtype = sig.real_dtype
    has_c = c is not None
    args = [jnp.real(a).astype(real_dtype), jnp.imag(a).astype(real_dtype),
            jnp.real(b).astype(real_dtype), jnp.imag(b).astype(real_dtype)]
    in_specs = [_a_spec(sig), _a_spec(sig), _b_spec(sig), _b_spec(sig)]
    if has_c:
        args += [jnp.real(c).astype(real_dtype),
                 jnp.imag(c).astype(real_dtype)]
        in_specs += [_c_spec(sig), _c_spec(sig)]
    alpha = complex(alpha)
    beta = complex(beta)
    kernel = functools.partial(_cx_body, sig, nk, K, alpha, beta, has_c,
                               real_dtype)
    kw = {}
    if not interpret:
        cp = _compiler_params()
        if cp is not None:
            kw["compiler_params"] = cp
    outr, outi = pl.pallas_call(
        kernel,
        grid=(gm, gn, nk),
        in_specs=in_specs,
        out_specs=[_c_spec(sig), _c_spec(sig)],
        out_shape=[jax.ShapeDtypeStruct((M, N), real_dtype),
                   jax.ShapeDtypeStruct((M, N), real_dtype)],
        scratch_shapes=[pltpu.VMEM((sig.bm, sig.bn), sig.acc_dtype)] * 3,
        interpret=interpret,
        **kw,
    )(*args)
    return lax.complex(outr, outi).astype(sig.dtype)


# --------------------------------------------------------------------------
# Differentiation: pallas_call with scratch has no JVP rule, so the real
# GEMM gets a custom VJP whose backward is itself two GEMMs (the BLAS
# adjoint identities), evaluated through XLA dot (small problems; on TPU
# these would re-enter the IAAT dispatch).
# --------------------------------------------------------------------------

def _adjoints(sig: KernelSig, a, b, dC, alpha):
    ta, tb = sig.trans[0], sig.trans[1]
    opA = a.T if ta == "T" else a
    opB = b.T if tb == "T" else b
    dOpA = alpha * jnp.dot(dC, opB.T,
                           preferred_element_type=jnp.float32)
    dOpB = alpha * jnp.dot(opA.T, dC,
                           preferred_element_type=jnp.float32)
    dA = (dOpA.T if ta == "T" else dOpA).astype(a.dtype)
    dB = (dOpB.T if tb == "T" else dOpB).astype(b.dtype)
    return dA, dB


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _real_region_nc(sig, alpha, beta, interpret, a, b):
    return _real_call(sig, a, b, None, alpha, beta, interpret)


def _real_region_nc_fwd(sig, alpha, beta, interpret, a, b):
    return _real_region_nc(sig, alpha, beta, interpret, a, b), (a, b)


def _real_region_nc_bwd(sig, alpha, beta, interpret, res, dC):
    a, b = res
    return _adjoints(sig, a, b, dC.astype(jnp.float32), alpha)


_real_region_nc.defvjp(_real_region_nc_fwd, _real_region_nc_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _real_region_c(sig, alpha, beta, interpret, a, b, c):
    return _real_call(sig, a, b, c, alpha, beta, interpret)


def _real_region_c_fwd(sig, alpha, beta, interpret, a, b, c):
    return _real_region_c(sig, alpha, beta, interpret, a, b, c), (a, b)


def _real_region_c_bwd(sig, alpha, beta, interpret, res, dC):
    a, b = res
    dA, dB = _adjoints(sig, a, b, dC.astype(jnp.float32), alpha)
    return dA, dB, (beta * dC.astype(jnp.float32)).astype(dC.dtype)


_real_region_c.defvjp(_real_region_c_fwd, _real_region_c_bwd)


# --------------------------------------------------------------------------
# Public builders.
# --------------------------------------------------------------------------

def gemm_region(sig: KernelSig, a, b, c=None, *, alpha=1.0, beta=0.0,
                interpret: bool = True):
    """Run one plan region: op(a) @ op(b) (+ beta*c) with kernel ``sig``.

    Operand shapes may be any size; the grid is derived with ceil-div and
    edges are masked as described in the module docstring.  Real dtypes
    are differentiable (custom VJP); complex kernels are forward-only
    (the paper's C/Z BLAS entries are not training paths)."""
    if sig.complex_:
        return _cx_call(sig, a, b, c, alpha, beta, interpret)
    if c is None:
        return _real_region_nc(sig, float(alpha), float(beta), interpret,
                               a, b)
    return _real_region_c(sig, float(alpha), float(beta), interpret,
                          a, b, c)


def make_gemm_kernel(sig: KernelSig, *, has_c_in: bool = False,
                     interpret: bool = False):
    """Install-time build: returns the specialised kernel callable."""
    def call(a, b, c=None, alpha=1.0, beta=0.0):
        if has_c_in and c is None:
            raise ValueError(f"{sig.name} built with has_c_in")
        return gemm_region(sig, a, b, c, alpha=alpha, beta=beta,
                           interpret=interpret)
    call.__name__ = sig.name
    call.sig = sig
    return call
