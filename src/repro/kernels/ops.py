"""Jit'd public wrappers for every kernel in this package.

These are the callables examples/benchmarks/models import.  Shape/flag
arguments that select a kernel instance are static; array arguments are
traced.  GEMM-shaped entries route through :mod:`repro.api` (one Policy
+ Router for every shape), so the paper's technique — and any measured
DeviceProfile — applies uniformly; the grouped entries resolve their
block sizes through ``api.route`` when the caller does not pin them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import api
from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_gemm as _gg
from repro.kernels import ssd as _ssd


def gemm(a, b, c=None, alpha=1.0, beta=0.0, trans_a=False, trans_b=False):
    """BLAS-style small-GEMM entry (input-aware dispatch)."""
    return api.gemm(a, b, c, alpha, beta, trans_a, trans_b)


def matmul(x, w):
    """Framework ND matmul (ambient policy)."""
    return api.matmul(x, w)


def _grouped_blocks(op, G, C, K, N, dtype, bm=None):
    dims = (G, bm if bm is not None else C, K, N)
    return api.route(op, dims, dtype).blocks


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def _batched_gemm_jit(x, w, *, interpret=True, blocks=None):
    return _gg.batched_gemm(x, w, interpret=interpret, blocks=blocks)


def batched_gemm(x, w, *, interpret=True, blocks=None):
    """Always-Pallas grouped kernel entry; ``blocks=None`` resolves the
    block sizes through the router (profile-refined under
    ``backend="tuned"``, the analytical table otherwise)."""
    if blocks is None:
        G, C, K = x.shape
        blocks = _grouped_blocks("batched_gemm", G, C, K, w.shape[-1],
                                 jnp.result_type(x.dtype, w.dtype))
    return _batched_gemm_jit(x, w, interpret=interpret, blocks=blocks)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "blocks"))
def _ragged_gemm_jit(x, w, tile_group_ids, *, bm=128, interpret=True,
                     blocks=None):
    return _gg.ragged_gemm(x, w, tile_group_ids, bm=bm,
                           interpret=interpret, blocks=blocks)


def ragged_gemm(x, w, tile_group_ids, *, bm=128, interpret=True,
                blocks=None):
    """Always-Pallas ragged kernel entry; block resolution as above (the
    row block ``bm`` stays caller-pinned — group sizes are traced)."""
    if blocks is None:
        T, K = x.shape
        G, _, N = w.shape
        blocks = _grouped_blocks("ragged_gemm", G, T, K, N,
                                 jnp.result_type(x.dtype, w.dtype), bm=bm)
    return _ragged_gemm_jit(x, w, tile_group_ids, bm=bm,
                            interpret=interpret, blocks=blocks)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "scale", "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    scale=None, bq=128, bkv=128, interpret=True):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale, bq=bq,
                               bkv=bkv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=True):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
