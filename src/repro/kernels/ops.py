"""Jit'd public wrappers for every kernel in this package.

These are the callables examples/benchmarks/models import.  Shape/flag
arguments that select a kernel instance are static; array arguments are
traced.  Each wrapper routes through the IAAT dispatch layer where the
paper's technique applies.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_gemm as _gg
from repro.kernels import ssd as _ssd


def gemm(a, b, c=None, alpha=1.0, beta=0.0, trans_a=False, trans_b=False):
    """BLAS-style small-GEMM entry (input-aware dispatch)."""
    return dispatch.iaat_gemm(a, b, c, alpha, beta, trans_a, trans_b)


@functools.partial(jax.jit, static_argnames=("trans_a", "trans_b",
                                             "alpha", "beta", "backend",
                                             "interpret", "method"))
def gemm_jit(a, b, c=None, *, alpha=1.0, beta=0.0, trans_a=False,
             trans_b=False, backend="auto", interpret=True, method="dp"):
    with dispatch.configure(backend=backend, interpret=interpret,
                            method=method):
        return dispatch.iaat_gemm(a, b, c, alpha, beta, trans_a, trans_b)


def matmul(x, w):
    return dispatch.matmul(x, w)


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def batched_gemm(x, w, *, interpret=True, blocks=None):
    return _gg.batched_gemm(x, w, interpret=interpret, blocks=blocks)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "blocks"))
def ragged_gemm(x, w, tile_group_ids, *, bm=128, interpret=True,
                blocks=None):
    return _gg.ragged_gemm(x, w, tile_group_ids, bm=bm,
                           interpret=interpret, blocks=blocks)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "scale", "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    scale=None, bq=128, bkv=128, interpret=True):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale, bq=bq,
                               bkv=bkv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=True):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
