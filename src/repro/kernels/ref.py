"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each kernel in this package has a reference here, used by the per-kernel
allclose tests and — for attention/SSD — by the XLA model path that the
multi-pod dry-run compiles (chunked formulations keep 32k+ sequences
compilable without materialising S x S score matrices).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


# --------------------------------------------------------------------------
# GEMM (paper oracle).
# --------------------------------------------------------------------------

def ref_gemm(a, b, c=None, alpha=1.0, beta=0.0, trans_a: bool = False,
             trans_b: bool = False):
    """C = alpha * op(A) @ op(B) + beta * C, computed by jnp."""
    opa = a.T if trans_a else a
    opb = b.T if trans_b else b
    if jnp.issubdtype(opa.dtype, jnp.complexfloating):
        out = jnp.asarray(alpha, opa.dtype) * (opa @ opb)
    else:
        acc = jnp.float64 if opa.dtype == jnp.float64 else jnp.float32
        out = (alpha * jnp.dot(opa, opb, preferred_element_type=acc))
        out = out.astype(jnp.result_type(a.dtype, b.dtype))
    if c is not None:
        out = out + jnp.asarray(beta, out.dtype) * c
    return out


def ref_grouped_gemm(x, w, group_sizes):
    """Per-group x[g_rows] @ w[g]: x (T, K), w (G, K, N), sizes (G,).

    Rows of x are laid out group-contiguously (sum(sizes) == T)."""
    G = w.shape[0]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(group_sizes.astype(jnp.int32))[:-1]])
    T = x.shape[0]
    row = jnp.arange(T)[:, None]
    out = jnp.zeros((T, w.shape[-1]), jnp.result_type(x.dtype, w.dtype))
    for g in range(G):
        sel = (row >= starts[g]) & (row < starts[g] + group_sizes[g])
        xg = jnp.where(sel, x, 0)
        out = out + jnp.where(sel, xg @ w[g], 0)
    return out


# --------------------------------------------------------------------------
# Attention.
# --------------------------------------------------------------------------

def _mask_bias(sq: int, sk: int, q_offset: int, causal: bool,
               window: Optional[int], dtype):
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def ref_mha(q, k, v, *, causal: bool = True, window: Optional[int] = None,
            q_offset: int = 0, scale: Optional[float] = None):
    """Quadratic reference attention. q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D).

    GQA: Hq must be a multiple of Hkv; kv heads are broadcast."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = logits + _mask_bias(Sq, k.shape[2], q_offset, causal, window,
                                 jnp.float32)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_mha(q, k, v, *, causal: bool = True,
                window: Optional[int] = None, q_offset: int = 0,
                scale: Optional[float] = None, kv_chunk: int = 1024):
    """Online-softmax attention scanning KV in chunks (flash-style, pure
    jnp + lax.scan).  This is both the oracle for the Pallas flash kernel
    at scale and the XLA model path used by the dry-run (memory O(S·c))."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    nc = -(Sk // -kv_chunk)
    pad = nc * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(B, Hkv, nc, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nc, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    qf = q
    qi = jnp.arange(Sq)[:, None] + q_offset

    def step(carry, xs):
        # NB: the chunk counter ci lives in the CARRY, not in xs — a
        # loop-carried value cannot be hoisted, whereas an xs-derived mask
        # gets strength-reduced by XLA into a materialised
        # (nc, B, H, Sq, chunk) bool tensor (gigabytes at 32k).
        m, l, acc, ci = carry
        kb, vb = xs
        kb = jnp.repeat(kb, rep, axis=1)
        vb = jnp.repeat(vb, rep, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb,
                       preferred_element_type=jnp.float32) * scale
        ki = ci * kv_chunk + jnp.arange(kv_chunk)[None, :]
        ok = ki < Sk
        if causal:
            ok = ok & (ki <= qi)
        if window is not None:
            ok = ok & (ki > qi - window)
        s = jnp.where(ok[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc, ci + 1), None

    from repro.parallel.ctx import constrain
    m0 = constrain(jnp.full((B, Hq, Sq), -jnp.inf, jnp.float32),
                   "batch", "heads", None)
    l0 = constrain(jnp.zeros((B, Hq, Sq), jnp.float32),
                   "batch", "heads", None)
    a0 = constrain(jnp.zeros((B, Hq, Sq, D), jnp.float32),
                   "batch", "heads", None, None)
    # checkpoint the chunk step: without it, the backward pass saves the
    # (nc, B, H, Sq, chunk) f32 score stack — gigabytes at 32k context
    (m, l, acc, _), _ = lax.scan(
        jax.checkpoint(step), (m0, l0, a0, jnp.zeros((), jnp.int32)),
        (kc, vc))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality).
# --------------------------------------------------------------------------

def ref_ssd_recurrent(x, dt, A, B, C, *, D_skip=None):
    """Ground-truth sequential recurrence (one step per token).

    x: (Bt, S, H, P); dt: (Bt, S, H); A: (H,) (negative);
    B, C: (Bt, S, G, N) with G == 1 broadcast over heads.
    h_t = exp(dt*A) h_{t-1} + dt * B_t x_t ;  y_t = C_t . h_t
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)[:, :, 0]   # (Bt, S, N)
    Cf = C.astype(jnp.float32)[:, :, 0]

    def step(h, t):
        # h: (Bt, H, P, N)
        da = jnp.exp(dtf[:, t] * A[None, :])            # (Bt, H)
        inp = (dtf[:, t, :, None, None] * xf[:, t, :, :, None]
               * Bf[:, t, None, None, :])               # (Bt,H,P,N)
        h = h * da[..., None, None] + inp
        y = jnp.einsum("bhpn,bn->bhp", h, Cf[:, t])
        return h, y

    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    _, ys = lax.scan(step, h0, jnp.arange(S))
    y = ys.transpose(1, 0, 2, 3)                         # (Bt,S,H,P)
    if D_skip is not None:
        y = y + D_skip[None, None, :, None] * xf
    return y.astype(x.dtype)


def ref_ssd(x, dt, A, B, C, *, D_skip=None, chunk: int = 64,
            return_state: bool = False):
    """Chunked SSD (the paper-of-record algorithm, arXiv:2405.21060 §6):
    intra-chunk 'attention-like' term + inter-chunk state recurrence.

    Mathematically identical to ``ref_ssd_recurrent``; O(S·c) memory.  This
    is the XLA model path; the Pallas kernel mirrors its block structure
    (each chunk is a cascade of small GEMMs — IAAT's habitat).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    nc = -(S // -chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = nc * chunk
    xf = x.astype(jnp.float32).reshape(Bt, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, chunk, H)
    Bf = B.astype(jnp.float32).reshape(Bt, nc, chunk, -1, N)[:, :, :, 0]
    Cf = C.astype(jnp.float32).reshape(Bt, nc, chunk, -1, N)[:, :, :, 0]

    # one chunk per scan step: the vectorised form materialises a
    # (Bt, nc, c, c, H) decay tensor — O(S·c·H) memory, terabytes at
    # production shapes.  The scan keeps the working set at one chunk
    # (exactly the Pallas kernel's schedule) and the checkpointed step
    # keeps the backward pass from stacking the per-chunk scores.
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(h, inputs):
        xc, dtc, Bc, Cc = inputs      # (Bt,c,H,P) (Bt,c,H) (Bt,c,N) (Bt,c,N)
        dA = dtc * A[None, None, :]                     # (Bt,c,H)
        cum = jnp.cumsum(dA, axis=1)                    # inclusive
        tot = cum[:, -1]                                # (Bt,H)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (Bt,t,s,H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(decay), 0.0)
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)
        scores = cb[..., None] * L * dtc[:, None]       # (Bt,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", scores, xc)
        y = y + jnp.einsum("btn,bhpn->bthp", Cc, h) * jnp.exp(cum)[..., None]
        w = (dtc * jnp.exp(tot[:, None] - cum))[..., None] * xc  # (Bt,c,H,P)
        h = h * jnp.exp(tot)[..., None, None] \
            + jnp.einsum("bchp,bcn->bhpn", w, Bc)
        return h, y

    from repro.parallel.ctx import constrain
    h0 = constrain(jnp.zeros((Bt, H, P, N), jnp.float32),
                   "batch", "ssm_heads", None, None)
    h_last, ys = lax.scan(
        jax.checkpoint(step), h0,
        (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
         Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bt, Sp, H, P)[:, :S]
    if D_skip is not None:
        y = y + D_skip[None, None, :, None] * x.astype(jnp.float32)[:, :S]
    y = y.astype(x.dtype)
    if return_state:
        return y, h_last
    return y


def ref_ssd_decode_step(h, x_t, dt_t, A, B_t, C_t):
    """One-token SSM recurrence for serving (state in, state out).

    h: (Bt,H,P,N); x_t: (Bt,H,P); dt_t: (Bt,H); B_t/C_t: (Bt,N)."""
    da = jnp.exp(dt_t * A[None, :])
    h = h * da[..., None, None] + (dt_t[..., None, None]
                                   * x_t[..., None] * B_t[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", h, C_t)
    return h, y


# --------------------------------------------------------------------------
# RMSNorm.
# --------------------------------------------------------------------------

def ref_rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
