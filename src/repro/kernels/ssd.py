"""Mamba-2 SSD (state-space duality) chunked scan kernel (Pallas TPU).

SSD computes attention-free sequence mixing as a cascade of *small GEMMs*
per chunk (C@Bᵀ (c x c), scores @ x (c x P), B'ᵀ @ x (N x P), C @ h
(c x P)) plus a tiny inter-chunk state recurrence — squarely IAAT's
small-GEMM regime, which is why this kernel lives in this framework: the
chunk size is an IAAT kernel-table choice (VMEM fit + MXU alignment), not
a hand-picked constant.

Layout: grid (B, H, n_chunks); the chunk axis is 'arbitrary' (sequential)
and the (P, N) state is carried across grid steps in a VMEM scratch —
Pallas guarantees scratch persistence along the trailing grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _body(chunk: int, S: int, nc: int,
          x_ref, dt_ref, da_ref, b_ref, c_ref, o_ref, h_ref):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (c,)  [lane-padded view]
    da = da_ref[0, :, 0].astype(jnp.float32)      # (c,)
    Bm = b_ref[0, :, 0].astype(jnp.float32)       # (c, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)       # (c, N)

    # sequence-tail mask (last chunk may overhang S)
    tpos = ci * chunk + jnp.arange(chunk)
    valid = tpos < S
    dt = jnp.where(valid, dt, 0.0)
    da = jnp.where(valid, da, 0.0)

    cum = jnp.cumsum(da)                           # (c,) inclusive
    seg_total = cum[-1]

    # intra-chunk: L[t,s] = exp(cum_t - cum_s) for s <= t
    diff = cum[:, None] - cum[None, :]
    tri = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    cb = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (c, c)
    scores = cb * L * dt[None, :]
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)  # (c, P)

    # inter-chunk: y += exp(cum_t) * C_t . h_prev
    h_prev = h_ref[...]                            # (N, P)
    y = y + jnp.exp(cum)[:, None] * jnp.dot(
        Cm, h_prev, preferred_element_type=jnp.float32)

    # state update: h = exp(total) h_prev + Σ_s exp(total - cum_s) dt_s B_s x_sᵀ
    w = (dt * jnp.exp(seg_total - cum))[:, None] * Bm   # (c, N)
    h_ref[...] = jnp.exp(seg_total) * h_prev + jnp.dot(
        w.T, x, preferred_element_type=jnp.float32)

    o_ref[0, :, 0] = y.astype(o_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool = True) -> jax.Array:
    """x: (Bt, S, H, P); dt: (Bt, S, H); A: (H,); B, C: (Bt, S, 1, N).

    Returns y: (Bt, S, H, P).  D-skip is applied by the caller."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    nc = -(S // -chunk)
    Sp = nc * chunk
    if Sp != S:
        x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    dA = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]
    # broadcast B/C across heads via index maps (G=1 in all assigned archs)
    body = functools.partial(_body, chunk, S, nc)
    out = pl.pallas_call(
        body,
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, dA, B, C)
    return out[:, :S]
