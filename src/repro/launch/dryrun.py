import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape x
# mesh) cell and extract memory / cost / collective statistics.  The two
# lines above MUST run before any jax import (jax locks the device count on
# first init); do NOT move them or set the flag globally.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                        # noqa: E402
from repro.configs.base import SHAPES, shape_applicable  # noqa: E402
from repro.launch import hlo_analyzer, hlo_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.common import XLA              # noqa: E402
from repro.models.registry import build as build_model  # noqa: E402
from repro.parallel import rules as R            # noqa: E402
from repro.parallel.ctx import activation_axes, activation_sharding  # noqa: E402
from repro.train import loop as train_loop       # noqa: E402

# per-(arch, shape) gradient-accumulation overrides (memory fitting; see
# EXPERIMENTS.md §Dry-run for the derivation)
ACCUM = {"train_4k": 8}
ACCUM_ARCH = {("mixtral-8x22b", "train_4k"): 16}


def input_specs(cfg, shape, mesh, rules) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    emb = jnp.bfloat16
    d = cfg.d_model
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), tok),
               "labels": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.frontend == "vision":
            out["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.frontend_tokens), tok)
            out["labels"] = jax.ShapeDtypeStruct((B, S - cfg.frontend_tokens), tok)
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, d), emb)
        if cfg.frontend == "audio":
            out["src_embeds"] = jax.ShapeDtypeStruct((B, S, d), emb)
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.frontend == "vision":
            out["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.frontend_tokens), tok)
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, d), emb)
        if cfg.frontend == "audio":
            out["src_embeds"] = jax.ShapeDtypeStruct((B, S, d), emb)
        return out
    # decode: one token; the cache is built separately
    return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}


def _cache_struct(model, cfg, shape):
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 jnp.bfloat16))


def _serving_params(model):
    """Serving deploys bf16 weights (no f32 master / optimizer state)."""
    structs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if jnp.issubdtype(s.dtype, jnp.floating) else s, structs)


def _cache_shardings(cache_struct, cfg, shape, mesh, rules):
    spec_by_name = R.cache_shardings(cfg, shape, mesh, rules)

    def one(path, leaf):
        name = path[-1].name if hasattr(path[-1], "name") else str(path[-1])
        return NamedSharding(mesh, spec_by_name.get(name, P()))

    # dataclass pytrees flatten positionally; rebuild by field name
    import dataclasses as dc
    kw = {}
    for f in dc.fields(cache_struct):
        v = getattr(cache_struct, f.name)
        if v is None:
            kw[f.name] = None
        else:
            kw[f.name] = NamedSharding(mesh, spec_by_name.get(f.name, P()))
    return type(cache_struct)(**kw)


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch     # decode: one token per seq


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, fsdp: bool = True, accum: Optional[int] = None,
             keep_hlo: bool = False) -> Dict[str, Any]:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    rules = R.make_rules(cfg, mesh, fsdp=fsdp)
    be = XLA
    act_axes = activation_axes(cfg, mesh, R.batch_spec(mesh, shape.global_batch))

    with mesh, activation_sharding(mesh, act_axes):
        if shape.kind == "train":
            acc = accum if accum is not None else ACCUM_ARCH.get(
                (arch, shape_name), ACCUM.get(shape_name, 1))
            tc = train_loop.TrainConfig(accum_steps=acc)
            step_fn = train_loop.make_train_step(model, tc, be)
            state_struct = jax.eval_shape(
                lambda: train_loop.init_train_state(model, jax.random.PRNGKey(0)))
            state_sh = rules.tree_shardings(train_loop.train_state_specs(model))
            batch_struct = input_specs(cfg, shape, mesh, rules)
            batch_sh = {k: R.data_shardings(cfg, shape, mesh, rules)[k]
                        for k in batch_struct}
            lowered = jax.jit(step_fn,
                              in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)) \
                .lower(state_struct, batch_struct)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill(params, batch, be)
            param_struct = _serving_params(model)
            param_sh = rules.tree_shardings(model.specs())
            batch_struct = input_specs(cfg, shape, mesh, rules)
            batch_sh = {k: R.data_shardings(cfg, shape, mesh, rules)[k]
                        for k in batch_struct}
            cache_struct = jax.eval_shape(
                lambda p, b: prefill_step(p, b)[1], param_struct, batch_struct)
            cache_sh = _cache_shardings(cache_struct, cfg, shape, mesh, rules)
            lowered = jax.jit(prefill_step,
                              in_shardings=(param_sh, batch_sh),
                              out_shardings=(None, cache_sh)) \
                .lower(param_struct, batch_struct)
        else:
            def serve_step(params, tokens, cache):
                return model.decode(params, {"tokens": tokens}, cache, be)
            param_struct = _serving_params(model)
            param_sh = rules.tree_shardings(model.specs())
            cache_struct = _cache_struct(model, cfg, shape)
            cache_sh = _cache_shardings(cache_struct, cfg, shape, mesh, rules)
            tok_struct = input_specs(cfg, shape, mesh, rules)["tokens"]
            tok_sh = R.data_shardings(cfg, shape, mesh, rules)["tokens"]
            lowered = jax.jit(serve_step,
                              in_shardings=(param_sh, tok_sh, cache_sh),
                              out_shardings=(None, cache_sh),
                              donate_argnums=(2,)) \
                .lower(param_struct, tok_struct, cache_struct)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_dev = mesh.size
    ca = hlo_stats.cost_analysis_terms(compiled)
    ma = hlo_stats.memory_analysis_terms(compiled)
    hlo = compiled.as_text()
    # lax.cond branch weights: fraction of scan iterations where the true
    # branch (apply-shared / global-attention) actually runs
    ctw, cfw = 1.0, 1.0
    if cfg.shared_attn_every:
        napps = -(cfg.n_layers // -cfg.shared_attn_every)
        ctw = napps / cfg.n_layers
        cfw = 1.0 - ctw
    elif cfg.attn.kind == "local_global":
        ctw = 1.0 / (cfg.attn.local_ratio + 1)     # true = global branch
        cfw = 1.0 - ctw
    st = hlo_analyzer.analyze(hlo, cond_true_weight=ctw,
                              cond_false_weight=cfw)
    coll = {k: int(v) for k, v in st.coll.items()}
    coll["total"] = int(st.coll_total)
    mf = model_flops(cfg, shape) / n_dev
    rl = hlo_stats.Roofline(flops=st.flops, hbm_bytes=st.traffic,
                            coll_bytes=st.coll_total, model_flops=mf)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_analysis": ca, "memory_analysis": ma,
        "collectives": coll, "model_flops_per_dev": mf,
        "roofline": rl.as_dict(),
        "analyzer": {"dots": st.dots, "loops": st.loops},
        "rules_fallbacks": rules.fallbacks,
        "hlo_bytes": len(hlo),
    }
    if keep_hlo:
        rec["hlo_text"] = hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if args.skip_existing and results.get(key, {}).get("status") == "ok":
                    print(f"[skip] {key}")
                    continue
                print(f"[cell] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, fsdp=not args.no_fsdp,
                                   accum=args.accum)
                except Exception as e:  # noqa: BLE001 — log and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" mem/dev={rec['memory_analysis'].get('total_nonalias', 0)/2**30:.2f}GiB"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[done] {key}: {status}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"dry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
