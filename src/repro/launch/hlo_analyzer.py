"""Trip-count-aware HLO analysis.

``compiled.cost_analysis()`` counts each while-loop BODY once, which
undercounts scanned-layer programs by O(layers x accum_steps).  This
module re-derives the three roofline inputs from the optimized HLO text,
multiplying each computation's contribution by the product of its
enclosing loops' ``known_trip_count`` values:

  * dot FLOPs        2 * prod(out_shape) * prod(contracted dims)
  * HBM traffic      sum over ops of (operand + output bytes), XLA
                     cost-analysis semantics, fusion-opaque
  * collective bytes per kind, output-shape bytes

``lax.cond`` branches (conditional ops) can be weighted by an explicit
fraction (e.g. zamba2's shared block runs on 14/81 of layer iterations);
default weight is 1 for both branches (structural upper bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "bitcast-convert", "reshape", "after-all",
                 "partition-id", "replica-id", "iota", "while",
                 "conditional", "call"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\\?{\\?"n\\?":\\?"(\d+)\\?"')
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def _split_shape_opcode(rest: str) -> Tuple[str, str, str]:
    """rest = everything after '= '. Returns (shape, opcode, args_line)."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == " " and depth == 0:
            shape = rest[:i]
            tail = rest[i + 1:]
            m = re.match(r"([\w\-]+)\(", tail)
            if not m:
                return shape, "", tail
            return shape, m.group(1), tail
    return rest, "", ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", line)
        if header and not line.lstrip().startswith("ROOT"):
            cur = Computation(header.group(1), {}, [])
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shape, opcode, args = _split_shape_opcode(rest)
        operands = _NAME_RE.findall(args.split(", sharding=")[0]) if args else []
        cur.ops[name] = Op(name, shape, opcode, line, operands)
        cur.order.append(name)
    return comps


def _entry_name(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation not referenced by anyone
    referenced = set()
    for c in comps.values():
        for op in c.ops.values():
            for attr in ("calls=", "to_apply=", "body=", "condition=",
                         "true_computation=", "false_computation=",
                         "branch_computations="):
                if attr in op.line:
                    referenced.update(_NAME_RE.findall(
                        op.line.split(attr, 1)[1].split(")")[0]))
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    dims = _shape_dims(op.shape)
    if not dims:
        return 0.0
    for d in dims[0][1]:
        out_elems *= d
    # contracted size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.line)
    k = 1
    if m and op.operands:
        lhs = comp.ops.get(op.operands[0])
        if lhs is not None:
            ldims = _shape_dims(lhs.shape)
            if ldims:
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(ldims[0][1]):
                        k *= ldims[0][1][int(ci)]
    return 2.0 * out_elems * k


def _op_traffic(op: Op, comp: Computation) -> float:
    if op.opcode in _SKIP_TRAFFIC or not op.opcode:
        return 0.0
    total = shape_bytes(op.shape)
    for o in op.operands:
        src = comp.ops.get(o)
        if src is not None and src.opcode not in ("constant",):
            total += shape_bytes(src.shape)
    return float(total)


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    traffic: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    dots: int = 0
    loops: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def analyze(text: str, *, cond_true_weight: float = 1.0,
            cond_false_weight: float = 1.0) -> HloStats:
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)
    stats = HloStats()
    seen_stack: List[str] = []

    def visit(cname: str, mult: float, traffic: bool = True) -> None:
        comp = comps.get(cname)
        if comp is None or cname in seen_stack:
            return
        seen_stack.append(cname)
        for name in comp.order:
            op = comp.ops[name]
            if op.opcode == "dot":
                stats.flops += mult * _dot_flops(op, comp)
                stats.dots += 1
            elif op.opcode == "convolution":
                stats.flops += mult * 2 * shape_bytes(op.shape)  # coarse
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                stats.coll[base] += mult * shape_bytes(op.shape)
            if traffic:
                stats.traffic += mult * _op_traffic(op, comp)
            # recurse
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.line)
                trips = int(tm.group(1)) if tm else 1
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                if mb:
                    stats.loops[mb.group(1)] = trips
                    visit(mb.group(1), mult * trips, traffic)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                if mc:
                    visit(mc.group(1), mult * trips, False)
            elif op.opcode == "conditional":
                mt = re.search(r"true_computation=%?([\w.\-]+)", op.line)
                mf = re.search(r"false_computation=%?([\w.\-]+)", op.line)
                if mt:
                    visit(mt.group(1), mult * cond_true_weight, traffic)
                if mf:
                    visit(mf.group(1), mult * cond_false_weight, traffic)
                mb = re.search(r"branch_computations={([^}]*)}", op.line)
                if mb:
                    for b in _NAME_RE.findall(mb.group(1)):
                        visit(b, mult, traffic)
            else:
                # fusion/reduce bodies: count dots, not traffic (registers)
                for attr in ("calls=", "to_apply="):
                    if attr in op.line:
                        tgt = _NAME_RE.findall(
                            op.line.split(attr, 1)[1].split(",")[0])
                        for t in tgt:
                            visit(t, mult, False)
        seen_stack.pop()

    visit(entry, 1.0, True)
    return stats
