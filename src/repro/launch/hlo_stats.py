"""Extract roofline terms from compiled XLA artifacts.

``cost_analysis`` supplies FLOPs + HBM bytes; collective bytes are NOT in
cost_analysis, so we parse the (optimized) HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, bucketed by op kind.  Hardware constants are the
graded v5e numbers (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link; 2D torus on v5e gives ~3 usable links/axis-pair

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,512,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind (done-ops skipped so
    async start/done pairs count once)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            seen_done += 1
            continue
        out[kind] += shape_bytes(shape_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                # per-device HLO flops
    hbm_bytes: float            # per-device HLO bytes accessed
    coll_bytes: float           # per-device collective bytes (on-device view)
    model_flops: float          # analytic 6·N·D (active) per device
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU bound implied by the dominant term."""
        if self.step_s == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.step_s

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def cost_analysis_terms(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": byt, "raw_keys": len(ca)}


def memory_analysis_terms(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_nonalias"] = (out.get("argument_size_in_bytes", 0)
                             + out.get("output_size_in_bytes", 0)
                             + out.get("temp_size_in_bytes", 0)
                             - out.get("alias_size_in_bytes", 0))
    return out
