"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because
the dry-run must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that act as pure data parallelism (pod is DP-only)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
