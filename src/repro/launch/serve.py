"""Serving launcher: continuous-batched generation with a smoke model.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 8 --max-new 16

Every decoder-only family serves through the paged slot-level engine
(attention K/V in the block pool, recurrent carries in per-slot state
rows); there is no wave fallback any more.  ``--engine auto`` is kept
as an alias for ``paged`` so existing invocations don't break, and the
``serve.engine_fallback`` counter records how often a family misses
the paged path (asserted 0 in tests for every registry family).
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro import api, configs, obs
from repro.models.registry import build as build_model
from repro.serve import PagedEngine, Request

log = logging.getLogger("repro.serve")


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "paged"))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default="xla",
                    choices=list(api.POLICY_NAMES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the flight-recorder timeline as a "
                         "Chrome-trace/Perfetto JSON after the run")
    ap.add_argument("--online-tune", action="store_true",
                    help="run the background traffic-aware re-tuner for "
                         "the engine's lifetime: hot size classes from "
                         "ROUTES.windowed() are re-timed on a budget and "
                         "merged into the live profile (kill switch: "
                         "REPRO_ONLINE_TUNE=0; pair with a routing "
                         "--backend — forced xla never calls route(), "
                         "so the tuner sees no traffic and idles)")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if cfg.family in ("encdec", "audio"):
        raise SystemExit("use a decoder-only arch for the serve demo")
    model = build_model(cfg)
    if model.paged_decode is None:
        # should be unreachable for any decoder-only registry family;
        # the counter is asserted 0 in tests so a regression that
        # reopens the engine split cannot land silently
        obs.counter("serve.engine_fallback").inc()
        raise SystemExit(f"--engine paged: family {cfg.family!r} has no "
                         f"paged serving path")
    # model-entry policy install: the engine snapshots the ambient policy
    be = api.install(api.named_policy(args.backend, interpret=True))
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)
    tuner = None
    if args.online_tune:
        from repro.tune.online import OnlineTuner
        # small-budget knobs: a smoke serve is short, so cycle fast and
        # time little — the point is the loop, not the profile quality
        tuner = OnlineTuner(interval_s=0.5, budget=4, top=1, reps=1)
    batcher = PagedEngine(model, params, be, slots=args.slots,
                          max_len=256, temperature=args.temperature,
                          seed=args.seed, block_size=args.block_size,
                          tuner=tuner)
    log.info("engine=paged arch=%s slots=%d online_tune=%s", args.arch,
             args.slots, bool(tuner))
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.randint(4, 24))
        prompt = rng.randint(0, cfg.vocab, plen).astype(np.int32)
        batcher.submit(Request(rid, prompt, max_new=args.max_new))
    done = batcher.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    for rid in sorted(done):
        log.info("req %d -> %d tokens: %s...", rid, len(done[rid]),
                 done[rid][:8])
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    if tuner is not None:
        print(f"online tuner: {tuner.cycles} cycles, {tuner.swaps} "
              f"profile swaps")
    if args.trace:
        from repro.obs import trace as trace_mod
        path = trace_mod.write_trace(args.trace, slots=args.slots)
        print(f"trace: {path} ({len(trace_mod.TRACE)} events, "
              f"{trace_mod.TRACE.dropped} dropped; open in "
              f"https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
