"""Serving launcher: continuous-batched generation with a smoke model.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 8 --max-new 16

``--engine auto`` (default) serves with the paged slot-level engine
whenever the family supports the block pool, falling back to the
wave-based reference for SSM/hybrid backbones.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro import api, configs
from repro.models.registry import build as build_model
from repro.serve import ContinuousBatcher, PagedEngine, Request

log = logging.getLogger("repro.serve")


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "paged", "wave"))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default="xla",
                    choices=list(api.POLICY_NAMES))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if cfg.family in ("encdec", "audio"):
        raise SystemExit("use a decoder-only arch for the serve demo")
    model = build_model(cfg)
    engine = args.engine
    if engine == "auto":
        engine = "paged" if model.paged_step is not None else "wave"
    elif engine == "paged" and model.paged_step is None:
        raise SystemExit(f"--engine paged: family {cfg.family!r} needs "
                         f"recurrent state the block pool doesn't carry; "
                         f"use --engine wave")
    # model-entry policy install: the engine snapshots the ambient policy
    be = api.install(api.named_policy(args.backend, interpret=True))
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)
    if engine == "paged":
        batcher = PagedEngine(model, params, be, slots=args.slots,
                              max_len=256, temperature=args.temperature,
                              seed=args.seed, block_size=args.block_size)
    else:
        batcher = ContinuousBatcher(model, params, be, slots=args.slots,
                                    max_len=256,
                                    temperature=args.temperature,
                                    seed=args.seed)
    log.info("engine=%s arch=%s slots=%d", engine, args.arch, args.slots)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.randint(4, 24))
        prompt = rng.randint(0, cfg.vocab, plen).astype(np.int32)
        batcher.submit(Request(rid, prompt, max_new=args.max_new))
    done = batcher.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    for rid in sorted(done):
        log.info("req %d -> %d tokens: %s...", rid, len(done[rid]),
                 done[rid][:8])
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
