"""End-to-end training launcher.

Runs on anything from the CPU host mesh (smoke configs, examples, CI) to
the production pod mesh — same code path: config -> mesh -> rules ->
sharded state -> train loop with checkpointing, fault handling, straggler
monitoring, deterministic data.

    PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --smoke --steps 50 --batch 8 --seq 128 \
        --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import time

import jax
import jax.numpy as jnp

from repro import api, configs, obs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import frontends
from repro.models.registry import build as build_model
from repro.parallel import rules as R
from repro.parallel.ctx import activation_axes, activation_sharding
from repro.train import checkpoint as ckpt_mod
from repro.train import data as data_mod
from repro.train import fault
from repro.train import loop as train_loop
from repro.train import optimizer as opt

log = logging.getLogger("repro.train")


def build_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--backend", default="xla",
                    choices=list(api.POLICY_NAMES))
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--inject-fault-at", type=int, default=-1,
                    help="simulate a node failure at this step (testing)")
    return ap.parse_args(argv)


def run(args) -> dict:
    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=args.multi_pod) \
        if args.production_mesh else make_host_mesh()
    rules = R.make_rules(cfg, mesh)
    # the single model-entry policy install: one frozen Policy for the
    # whole run, threaded to every layer (no per-projection re-config).
    # Training differentiates through the model, and the pallas
    # flash-attention/SSD kernels have no JVP — so the non-GEMM kernel
    # family is pinned to the XLA/ref paths while GEMM routing stays
    # input-aware (auto) or profile-refined (tuned): the routed GEMM
    # plan path carries a custom VJP.
    be = api.install(api.named_policy(args.backend,
                                      interpret=True).replace(kernels="xla"))
    tc = train_loop.TrainConfig(
        opt=opt.OptConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                          decay_steps=max(args.steps, 10)),
        accum_steps=args.accum)
    step_fn = train_loop.make_train_step(model, tc, be)
    state_specs = train_loop.train_state_specs(model)
    state_sh = rules.tree_shardings(state_specs)
    data = data_mod.SyntheticTokens(cfg.vocab, args.seq, args.batch,
                                    seed=args.seed)
    ckpt = ckpt_mod.Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    monitor = fault.StepMonitor()
    act_axes = activation_axes(cfg, mesh, R.batch_spec(mesh, args.batch))
    shape = configs.base.ShapeConfig("cli", args.seq, args.batch, "train")
    data_sh = R.data_shardings(cfg, shape, mesh, rules)
    metrics_out = {}

    def train_once(attempt: int) -> int:
        with mesh, activation_sharding(mesh, act_axes):
            start_step = 0
            state = None
            if ckpt and (args.resume or attempt > 0):
                latest = ckpt.latest_step()
                if latest is not None:
                    like = jax.eval_shape(
                        lambda: train_loop.init_train_state(
                            model, jax.random.PRNGKey(args.seed)))
                    state, extra = ckpt.restore(like, shardings=state_sh)
                    start_step = int(extra.get("data_step", latest))
                    log.info("restored step %d", start_step)
            if state is None:
                state = jax.jit(
                    lambda k: train_loop.init_train_state(model, k),
                    out_shardings=state_sh)(jax.random.PRNGKey(args.seed))
            jit_step = jax.jit(step_fn, in_shardings=(state_sh, None),
                               out_shardings=(state_sh, None),
                               donate_argnums=(0,))
            for step in range(start_step, args.steps):
                if step == args.inject_fault_at and attempt == 0:
                    raise fault.SimulatedFault(f"injected at step {step}")
                monitor.start()
                t0 = time.perf_counter()
                with obs.span("train.step"):
                    hb = data.batch(step, host=jax.process_index(),
                                    num_hosts=jax.process_count())
                    gb = data_mod.make_global_batch(hb, data_sh)
                    state, m = jit_step(state, gb)
                    m = {k: float(v) for k, v in m.items()}
                monitor.stop(step)
                train_loop.record_step(step, m,
                                       time.perf_counter() - t0)
                metrics_out.update(m, step=step)
                if step % args.log_every == 0 or step == args.steps - 1:
                    log.info("step %d loss %.4f gnorm %.3f lr %.2e",
                             step, m["loss"], m.get("grad_norm", 0),
                             m.get("lr", 0))
                if ckpt and args.ckpt_every and \
                        (step + 1) % args.ckpt_every == 0:
                    ckpt.save(step + 1, state,
                              extra={"data_step": step + 1}, async_=True)
            if ckpt:
                ckpt.save(args.steps, state,
                          extra={"data_step": args.steps})
                ckpt.wait()
            return args.steps

    final = fault.run_with_restarts(train_once,
                                    max_restarts=args.max_restarts)
    metrics_out["final_step"] = final
    metrics_out["monitor"] = monitor.summary()
    return metrics_out


def main() -> None:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    out = run(build_args())
    print({k: v for k, v in out.items()})


if __name__ == "__main__":
    main()
