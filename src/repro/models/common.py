"""Shared model machinery: the IAAT matmul hook, norms, RoPE, init/spec
utilities.

The ``be`` threaded through the model stack is a
:class:`repro.api.Policy` — the one frozen routing config — so the
layers consult the router directly; ``mm`` never re-enters a contextvar
per projection.  (The old two-axis ``Backend`` selector is gone; use
``api.Policy`` / ``api.named_policy``.)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import api
from repro.api import Policy

Params = Dict[str, Any]
Specs = Dict[str, Any]

#: Canonical policies for the two reference operating points: the
#: XLA-compilable dry-run stack, and pallas kernels with input-aware
#: GEMM routing under interpret mode (the CI container).
XLA = api.named_policy("xla")
PALLAS_INTERPRET = api.named_policy("pallas")


def mm(x: jax.Array, w: jax.Array,
       be: Optional[Policy] = None) -> jax.Array:
    """The framework matmul: every projection goes through here, so the
    paper's input-aware dispatch applies uniformly.  ``be`` defaults to
    the ambient installed policy (``api.install``/``api.using``)."""
    return api.matmul(x, w.astype(x.dtype), policy=be)


def rmsnorm(x: jax.Array, w: Optional[jax.Array], eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    D = x.shape[-1]
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freq  # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# init / spec utilities.
# --------------------------------------------------------------------------

def ninit(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def stack_init(init_fn, key, n: int) -> Params:
    """vmap a per-layer init over ``n`` layers -> stacked ("layers", ...)"""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def stack_specs(specs: Specs) -> Specs:
    """Prepend the "layers" logical axis to every spec in the tree."""
    return jax.tree.map(lambda s: ("layers",) + tuple(s), specs,
                        is_leaf=lambda s: isinstance(s, tuple))


def assert_same_structure(params: Params, specs: Specs) -> None:
    pt = jax.tree.structure(params)
    st = jax.tree.structure(specs, is_leaf=lambda s: isinstance(s, tuple))
    if pt != st:
        raise ValueError(f"param/spec structure drift:\n{pt}\nvs\n{st}")


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def count_params(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
