"""Encoder-decoder backbone (seamless-m4t-v2 assignment entry).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_src, d) from ``input_specs``.  The
decoder is a causal LM stack with cross-attention into the encoder states;
serving caches both the self-attention KV ring and the projected cross KV.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.api import Policy
from repro.models.common import (mm, ninit, rmsnorm, stack_init,
                                 stack_specs)
from repro.models.lm import LMCache, _remat


def _norm(cfg, dtype):
    return jnp.ones((cfg.d_model,), dtype) if cfg.parametric_norm else None


def _init_enc_block(cfg: ModelConfig, dtype):
    def init(key):
        ks = jax.random.split(key, 2)
        return {"ln1": _norm(cfg, dtype),
                "attn": L.init_attention(ks[0], cfg, dtype),
                "ln2": _norm(cfg, dtype),
                "mlp": L.init_mlp(ks[1], cfg, dtype=dtype)}
    return init


def _init_dec_block(cfg: ModelConfig, dtype):
    def init(key):
        ks = jax.random.split(key, 3)
        return {"ln1": _norm(cfg, dtype),
                "self_attn": L.init_attention(ks[0], cfg, dtype),
                "ln_x": _norm(cfg, dtype),
                "cross_attn": L.init_attention(ks[1], cfg, dtype),
                "ln2": _norm(cfg, dtype),
                "mlp": L.init_mlp(ks[2], cfg, dtype=dtype)}
    return init


def init_encdec(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    d, Vp = cfg.d_model, cfg.vocab_padded
    return {
        "embed": ninit(ks[0], (Vp, d), d ** -0.5, dtype),
        "enc_blocks": stack_init(_init_enc_block(cfg, dtype), ks[1],
                                 cfg.n_encoder_layers),
        "enc_norm": _norm(cfg, dtype),
        "dec_blocks": stack_init(_init_dec_block(cfg, dtype), ks[2],
                                 cfg.n_layers),
        "final_norm": _norm(cfg, dtype),
        "unembed": ninit(ks[3], (d, Vp), 1.0 / math.sqrt(d), dtype),
    }


def encdec_specs(cfg: ModelConfig) -> Dict:
    n = ("embed",) if cfg.parametric_norm else None
    a = L.attention_specs(cfg)
    m = L.mlp_specs(cfg)
    return {
        "embed": ("vocab", None),
        "enc_blocks": stack_specs({"ln1": n, "attn": a, "ln2": n, "mlp": m}),
        "enc_norm": n,
        "dec_blocks": stack_specs({"ln1": n, "self_attn": a, "ln_x": n,
                                   "cross_attn": a, "ln2": n, "mlp": m}),
        "final_norm": n,
        "unembed": (None, "vocab"),
    }


def encode(params, cfg: ModelConfig, be: Policy, src_embeds) -> jax.Array:
    """src_embeds: (B, S_src, d) (stubbed frontend output)."""
    x = src_embeds.astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1])

    def body(x, blk):
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        x = x + L.attention(blk["attn"], h, be, cfg, causal=False,
                            positions=positions)
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        return x + L.mlp(blk["mlp"], h, be), None

    x, _ = lax.scan(_remat(body, cfg), x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(blk, enc, cfg, be):
    Hkv, hd = cfg.n_kv_heads_padded, cfg.head_dim_
    B, Ssrc, _ = enc.shape
    k = mm(enc, blk["cross_attn"]["wk"], be).reshape(
        B, Ssrc, Hkv, hd).transpose(0, 2, 1, 3)
    v = mm(enc, blk["cross_attn"]["wv"], be).reshape(
        B, Ssrc, Hkv, hd).transpose(0, 2, 1, 3)
    return k, v


def _dec_block(blk, x, enc_or_kv, cfg, be, *, positions=None, kv=None,
               pos=None, precomputed_cross: bool = False):
    h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
    out = L.attention(blk["self_attn"], h, be, cfg, causal=True,
                      positions=positions, kv_cache=kv, pos=pos)
    if kv is not None:
        sa, kv_new = out
    else:
        sa, kv_new = out, None
    x = x + sa
    h = rmsnorm(x, blk["ln_x"], cfg.norm_eps)
    ckv = enc_or_kv if precomputed_cross else _cross_kv(blk, enc_or_kv, cfg, be)
    x = x + L.attention(blk["cross_attn"], h, be, cfg, cross_kv=ckv)
    h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
    return x + L.mlp(blk["mlp"], h, be), kv_new


def forward_train(params, cfg: ModelConfig, be: Policy, tokens,
                  src_embeds) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training: (logits (B, S_tgt, Vp), aux=0)."""
    enc = encode(params, cfg, be, src_embeds)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1])

    def body(x, blk):
        x, _ = _dec_block(blk, x, enc, cfg, be, positions=positions)
        return x, None

    x, _ = lax.scan(_remat(body, cfg), x, params["dec_blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return mm(x, params["unembed"], be), jnp.zeros((), jnp.float32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EncDecCache:
    pos: jax.Array
    self_k: jax.Array            # (L, B, Hkv, W, hd)
    self_v: jax.Array
    cross_k: jax.Array           # (L, B, Hkv, S_src, hd)
    cross_v: jax.Array

    def tree_flatten(self):
        return ((self.pos, self.self_k, self.self_v, self.cross_k,
                 self.cross_v), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, src_len: int,
               dtype=jnp.bfloat16, prefill_len: int = 0) -> EncDecCache:
    Hkv, hd, Ld = cfg.n_kv_heads_padded, cfg.head_dim_, cfg.n_layers
    return EncDecCache(
        pos=jnp.asarray(prefill_len, jnp.int32),
        self_k=jnp.zeros((Ld, batch, Hkv, seq_len, hd), dtype),
        self_v=jnp.zeros((Ld, batch, Hkv, seq_len, hd), dtype),
        cross_k=jnp.zeros((Ld, batch, Hkv, src_len, hd), dtype),
        cross_v=jnp.zeros((Ld, batch, Hkv, src_len, hd), dtype),
    )


def prefill(params, cfg: ModelConfig, be: Policy, tokens, src_embeds,
            cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, EncDecCache]:
    enc = encode(params, cfg, be, src_embeds)
    B, Stgt = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    positions = jnp.arange(Stgt)

    def body(x, blk):
        h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
        sa, (k, v) = L.attention(blk["self_attn"], h, be, cfg, causal=True,
                                 positions=positions, return_kv=True)
        x = x + sa
        h = rmsnorm(x, blk["ln_x"], cfg.norm_eps)
        ck, cv = _cross_kv(blk, enc, cfg, be)
        x = x + L.attention(blk["cross_attn"], h, be, cfg, cross_kv=(ck, cv))
        h = rmsnorm(x, blk["ln2"], cfg.norm_eps)
        return x + L.mlp(blk["mlp"], h, be), (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = lax.scan(body, x, params["dec_blocks"])
    W = cache_len or Stgt
    if W > Stgt:
        pad = ((0, 0),) * 3 + ((0, W - Stgt), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = EncDecCache(pos=jnp.asarray(Stgt, jnp.int32),
                        self_k=ks.astype(cfg.compute_dtype),
                        self_v=vs.astype(cfg.compute_dtype),
                        cross_k=cks.astype(cfg.compute_dtype),
                        cross_v=cvs.astype(cfg.compute_dtype))
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return mm(x, params["unembed"], be)[:, 0], cache


def decode(params, cfg: ModelConfig, be: Policy, tokens,
           cache: EncDecCache) -> Tuple[jax.Array, EncDecCache]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    pos = cache.pos

    def body(x, xs):
        blk, kb, vb, ck, cv = xs
        x, (kn, vn) = _dec_block(blk, x, (ck, cv), cfg, be, kv=(kb, vb),
                                 pos=pos, precomputed_cross=True)
        return x, (kn, vn)

    x, (kn, vn) = lax.scan(body, x, (params["dec_blocks"], cache.self_k,
                                     cache.self_v, cache.cross_k,
                                     cache.cross_v))
    cache = EncDecCache(pos=pos + 1, self_k=kn, self_v=vn,
                        cross_k=cache.cross_k, cross_v=cache.cross_v)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return mm(x, params["unembed"], be)[:, 0], cache
