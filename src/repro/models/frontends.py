"""Modality frontend STUBS (per the assignment: `[audio]`/`[vlm]` entries
specify the transformer backbone only; ``input_specs()`` provides
precomputed frame/patch embeddings).

These helpers exist so smoke tests / examples can fabricate plausible
frontend outputs, and so the shape contract is written down in one place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int, seq_len: int):
    """Shape of the precomputed embeddings the backbone consumes."""
    if cfg.frontend == "vision":
        return (batch, cfg.frontend_tokens, cfg.d_model)
    if cfg.frontend == "audio":
        return (batch, seq_len, cfg.d_model)   # encoder frames
    return None


def fake_frontend(key, cfg: ModelConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16) -> jax.Array:
    shape = frontend_embed_shape(cfg, batch, seq_len)
    if shape is None:
        raise ValueError(f"{cfg.name} has no frontend")
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.02


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens accompanying the frontend prefix (VLM)."""
    if cfg.frontend == "vision":
        return seq_len - cfg.frontend_tokens
    return seq_len
