"""Transformer layers: GQA attention (full / sliding-window / cross),
gated MLP, and capacity-routed MoE with sort-based dispatch.

Every projection goes through ``common.mm`` (the IAAT dispatch hook); the
attention inner loop switches between the Pallas flash kernel and the
chunked-XLA oracle by the ``Policy``; MoE expert compute switches between
``ops.batched_gemm`` (Pallas, the paper's batched-small-GEMM habitat) and
a batched einsum (XLA path for the multi-pod dry-run).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ref
from repro.api import Policy
from repro.models.common import mm, ninit, rmsnorm, rope
from repro.parallel.ctx import constrain


# --------------------------------------------------------------------------
# Attention.
# --------------------------------------------------------------------------

def _zero_pad_cols(w, cols: int):
    return jnp.pad(w, ((0, 0), (0, cols - w.shape[1]))) \
        if cols > w.shape[1] else w


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    Hp, Hkvp = cfg.n_heads_padded, cfg.n_kv_heads_padded
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(H * hd) / math.sqrt(2.0 * cfg.n_layers)
    # dead (padding) heads are ZERO so they contribute nothing and their
    # gradients are identically zero (see ModelConfig.head_pad_multiple)
    wq = _zero_pad_cols(ninit(ks[0], (d, H * hd), s, dtype), Hp * hd)
    wk = _zero_pad_cols(ninit(ks[1], (d, Hkv * hd), s, dtype), Hkvp * hd)
    wv = _zero_pad_cols(ninit(ks[2], (d, Hkv * hd), s, dtype), Hkvp * hd)
    wo = _zero_pad_cols(ninit(ks[3], (H * hd, d), so, dtype).T,
                        Hp * hd).T
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}


def attention_specs(cfg: ModelConfig) -> Dict:
    return {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }


def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    B, H, S, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * hd)


def _full_attn(q, k, v, be: Policy, *, causal, window, q_offset, scale):
    if be.pallas:
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale,
                                   bq=min(128, q.shape[2]),
                                   interpret=be.interpret)
    return ref.chunked_mha(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, scale=scale,
                           kv_chunk=min(1024, k.shape[2]))


def decode_attend(q, k_buf, v_buf, pos, *, window: Optional[int],
                  scale: float):
    """One-token attention over a (ring) KV buffer.

    q: (B, H, 1, hd); k_buf/v_buf: (B, Hkv, W, hd); ``pos`` is the position
    of the query token (the buffer already contains it at slot pos % W).
    Slot s holds position  p_s = pos - ((pos - s) mod W)  — for a
    full-length buffer this degenerates to p_s = s, so one formula covers
    both the ring (sliding-window) and the linear (full) cache."""
    B, H, _, hd = q.shape
    Hkv, W = k_buf.shape[1], k_buf.shape[2]
    rep = H // Hkv
    s_idx = jnp.arange(W)
    p_s = pos - jnp.mod(pos - s_idx, W)
    ok = p_s >= 0
    if window is not None:
        ok &= p_s > pos - window
    qf = q.reshape(B, Hkv, rep, hd)
    # preferred_element_type keeps the accumulation in f32 WITHOUT
    # materialising an f32 copy of the (huge) KV buffers
    logits = jnp.einsum("bkrd,bksd->bkrs", qf, k_buf,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(ok[None, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrs,bksd->bkrd", p.astype(v_buf.dtype), v_buf,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, 1, hd).astype(q.dtype)


def paged_attend(q, k_pool, v_pool, block_table, q_pos, *,
                 scale: float, window: Optional[int] = None,
                 decode_from=None):
    """Attention over a paged KV pool, read through a block table.

    q: (B, H, C, hd); k_pool/v_pool: (P, Hkv, BS, hd) — one layer's
    block pool; block_table: (B, nmax) int32 pool ids in *logical*
    order (padded with the null block 0); q_pos: (B, C) absolute query
    positions.  Because the table lists blocks logically, flattened key
    index j of the gathered (B, Hkv, nmax*BS, hd) buffer holds sequence
    position j — the mask is simply ``j <= q_pos`` (causal over the
    request's own history; stale/pad slots beyond ``q_pos`` and other
    requests' blocks are unreachable by construction).

    The branches mirror the wave engine's reference numerics
    operation-for-operation — normalised-probs rounding for decode
    tokens (:func:`decode_attend`) and flash-style unnormalised
    accumulation for prefill rows (``ref.chunked_mha``) — so that at
    temperature 0 the paged engine is token-identical to the wave
    reference, not merely close (masked lanes contribute exact zeros
    either way).  ``decode_from`` (B,) marks where the ORIGINAL decode
    boundary sits: a recompute-resume chunk replays positions that the
    reference timeline processed one token at a time, so rows at
    ``q_pos >= decode_from`` select the decode numerics even inside a
    C > 1 chunk — without this the replayed rows pick up flash-vs-
    softmax rounding, the recurrent carries inherit it, and the
    continuation after preemption drifts off the oracle."""
    B, H, C, hd = q.shape
    Hkv, BS = k_pool.shape[1], k_pool.shape[2]
    nmax = block_table.shape[1]
    rep = H // Hkv
    # gather the request's blocks: (B, nmax, Hkv, BS, hd) -> (B, Hkv, S, hd)
    kg = k_pool[block_table].transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, nmax * BS, hd)
    vg = v_pool[block_table].transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, nmax * BS, hd)
    key_pos = jnp.arange(nmax * BS)
    ok = key_pos[None, None, :] <= q_pos[:, :, None]          # (B, C, S)
    if window is not None:
        ok &= key_pos[None, None, :] > q_pos[:, :, None] - window
    if C == 1:
        # decode: decode_attend's grouped-GQA, normalised-softmax order
        qf = q.reshape(B, Hkv, rep, hd)
        logits = jnp.einsum("bkrd,bksd->bkrs", qf, kg,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(ok[:, None, None, 0, :], logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkrs,bksd->bkrd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, H, 1, hd).astype(q.dtype)
    # prefill chunk: chunked_mha's repeated-KV, unnormalised-exp order
    kb = jnp.repeat(kg, rep, axis=1)
    vb = jnp.repeat(vg, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kb,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(ok[:, None], s, -jnp.inf)
    m = s.max(-1)                      # rows always see >= 1 valid key
    p = jnp.exp(s - m[..., None])
    p = jnp.where(ok[:, None], p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                     preferred_element_type=jnp.float32)
    flash = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype)
    if decode_from is None:
        return flash
    # recompute-resume: replayed decode rows take decode_attend's
    # op-for-op numerics (same grouped-GQA shapes, batched over C)
    qf = q.reshape(B, Hkv, rep, C, hd)
    logits = jnp.einsum("bkrqd,bksd->bkrqs", qf, kg,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(ok[:, None, None], logits, -jnp.inf)
    pd = jax.nn.softmax(logits, axis=-1)
    outd = jnp.einsum("bkrqs,bksd->bkrqd", pd.astype(vg.dtype), vg,
                      preferred_element_type=jnp.float32)
    outd = outd.reshape(B, H, C, hd).astype(q.dtype)
    replay = q_pos >= decode_from[:, None]                    # (B, C)
    return jnp.where(replay[:, None, :, None], outd, flash)


def attention(p: Dict, x, be: Policy, cfg: ModelConfig, *,
              causal: bool = True, window: Optional[int] = None,
              positions=None, kv_cache: Optional[Tuple] = None,
              pos=None, cross_kv: Optional[Tuple] = None,
              paged_kv: Optional[Tuple] = None,
              return_kv: bool = False):
    """Unified attention layer.

    Modes:
      train/prefill: kv_cache None; positions (S,) or (B,S).
      decode:        kv_cache (k_buf, v_buf); pos scalar; x is (B,1,d).
      paged:         paged_kv (k_pool, v_pool, block_table, pos (B,C));
                     writes the chunk through the table, attends via
                     the gather path; one code path serves chunked
                     prefill (C>1) and slot decode (C=1).
      cross:         cross_kv (k, v) precomputed from encoder states.
    Returns y [, new_kv or (k,v) when return_kv]."""
    H, Hkv, hd = cfg.n_heads_padded, cfg.n_kv_heads_padded, cfg.head_dim_
    scale = hd ** -0.5
    B, S, _ = x.shape
    q = _split_heads(mm(x, p["wq"], be), H, hd)
    if cross_kv is not None:
        k, v = cross_kv
        y = _full_attn(q, k, v, be, causal=False, window=None, q_offset=0,
                       scale=scale)
        return mm(_merge_heads(y), p["wo"], be)
    q = constrain(q, "batch", "heads", None, None)
    k = _split_heads(mm(x, p["wk"], be), Hkv, hd)
    v = _split_heads(mm(x, p["wv"], be), Hkv, hd)
    k = constrain(k, "batch", "kv", None, None)
    v = constrain(v, "batch", "kv", None, None)
    if paged_kv is not None:
        # paged: rope at absolute positions, write the chunk through the
        # block table, attend over the gathered pool
        if len(paged_kv) == 5:
            k_pool, v_pool, bt, qpos, decode_from = paged_kv
        else:
            k_pool, v_pool, bt, qpos = paged_kv
            decode_from = None
        BS = k_pool.shape[2]
        q = rope(q, qpos, cfg.rope_theta)
        k = rope(k, qpos, cfg.rope_theta)
        blk = jnp.take_along_axis(bt, (qpos // BS).astype(jnp.int32),
                                  axis=1)                     # (B, C)
        off = jnp.mod(qpos, BS).astype(jnp.int32)
        # advanced indices at dims 0 and 2 -> update shape (B, C, Hkv, hd)
        k_pool = k_pool.at[blk, :, off, :].set(
            k.transpose(0, 2, 1, 3).astype(k_pool.dtype))
        v_pool = v_pool.at[blk, :, off, :].set(
            v.transpose(0, 2, 1, 3).astype(v_pool.dtype))
        y = paged_attend(q, k_pool, v_pool, bt, qpos, window=window,
                         scale=scale, decode_from=decode_from)
        return mm(_merge_heads(y), p["wo"], be), (k_pool, v_pool)
    if kv_cache is not None:
        # decode: rope at absolute position, ring-write, attend buffer
        k_buf, v_buf = kv_cache
        W = k_buf.shape[2]
        pos_arr = jnp.full((B, 1), pos, jnp.int32)
        q = rope(q, pos_arr, cfg.rope_theta)
        k = rope(k, pos_arr, cfg.rope_theta)
        slot = jnp.mod(pos, W).astype(jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        idx = (zero, zero, slot, zero)
        k_buf = lax.dynamic_update_slice(k_buf, k.astype(k_buf.dtype), idx)
        v_buf = lax.dynamic_update_slice(v_buf, v.astype(v_buf.dtype), idx)
        y = decode_attend(q, k_buf, v_buf, pos, window=window, scale=scale)
        return mm(_merge_heads(y), p["wo"], be), (k_buf, v_buf)
    if positions is None:
        positions = jnp.arange(S)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    y = _full_attn(q, k, v, be, causal=causal, window=window, q_offset=0,
                   scale=scale)
    out = mm(_merge_heads(y), p["wo"], be)
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU).
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    sd = 1.0 / math.sqrt(ff) / math.sqrt(2.0 * cfg.n_layers)
    return {"wg": ninit(ks[0], (d, ff), s, dtype),
            "wu": ninit(ks[1], (d, ff), s, dtype),
            "wd": ninit(ks[2], (ff, d), sd, dtype)}


def mlp_specs(cfg: ModelConfig) -> Dict:
    return {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"),
            "wd": ("mlp", "embed")}


def mlp(p: Dict, x, be: Policy):
    h = jax.nn.silu(mm(x, p["wg"], be)) * mm(x, p["wu"], be)
    h = constrain(h, "batch", None, "mlp")
    return mm(h, p["wd"], be)


# --------------------------------------------------------------------------
# MoE: top-k routing, sort-based capacity dispatch, grouped small GEMM.
# --------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_expert
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    sd = 1.0 / math.sqrt(f) / math.sqrt(2.0 * cfg.n_layers)
    return {
        "router": ninit(ks[0], (d, E), s, jnp.float32),
        "w_gate": ninit(ks[1], (E, d, f), s, dtype),
        "w_up": ninit(ks[2], (E, d, f), s, dtype),
        "w_down": ninit(ks[3], (E, f, d), sd, dtype),
    }


def moe_specs(cfg: ModelConfig) -> Dict:
    return {"router": ("embed", None),
            "w_gate": ("experts", "embed", "expert_mlp"),
            "w_up": ("experts", "embed", "expert_mlp"),
            "w_down": ("experts", "expert_mlp", "embed")}


def _capacity(T: int, m) -> int:
    c = int(math.ceil(T * m.top_k / m.num_experts * m.capacity_factor))
    # 128-multiples: MXU-aligned AND divisible by the data axis so the
    # (E, C, d) dispatch buffer shards its capacity dim
    grain = 128 if c >= 128 else 8
    return max(grain, -(c // -grain) * grain)


def _moe_dispatch(router, xf, cfg: ModelConfig, C: int):
    """Route + sort + capacity for one token shard.  xf: (T, d).

    Returns (buf (E, C, d), combine metadata, aux).  Gather-only data
    movement: the ONLY scatters are int32 slot maps (a (T*k, d) row
    scatter lowers to a per-element sort on some backends — measured
    7.5 GiB u32 temps)."""
    m = cfg.moe
    T, d = xf.shape
    E, k = m.num_experts, m.top_k

    logits = jnp.matmul(xf.astype(jnp.float32), router)           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)                            # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                    # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = (jnp.arange(T * k) // k)[order]
    counts = jnp.bincount(flat_e, length=E)                       # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - starts[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)                  # OOB=drop

    inv = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(
        stok, mode="drop")                                        # slot->token
    filled = jnp.zeros((E * C + 1,), jnp.bool_).at[dest].set(
        keep, mode="drop")
    buf = jnp.where(filled[:E * C, None],
                    xf.at[inv[:E * C]].get(mode="clip"), 0)
    slot_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.where(keep, dest, E * C).astype(jnp.int32))           # (T*k,)

    me = probs.mean(0)                                            # (E,)
    ce = (counts / jnp.maximum(counts.sum(), 1)).astype(jnp.float32)
    aux = m.aux_loss * E * jnp.sum(me * ce) \
        + m.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    return buf.reshape(E, C, d), (slot_flat, top_p), aux


def _moe_combine(out_buf, meta, T: int, k: int):
    """Per-token gather of its k expert rows (no (T, d) scatter); the
    weighted sum runs in bf16 with an f32 accumulator so any cross-shard
    reduction moves bf16, not f32."""
    slot_flat, top_p = meta
    EC, d = out_buf.shape[0] * out_buf.shape[1], out_buf.shape[2]
    rows = out_buf.reshape(EC, d).at[slot_flat].get(
        mode="fill", fill_value=0).reshape(T, k, d)
    # plain (non-f32-accumulated) einsum: k <= 8 terms, and an f32
    # preferred type would make the rows cotangent f32 — doubling the EP
    # combine all-reduce
    return jnp.einsum("tkd,tk->td", rows, top_p.astype(rows.dtype))


def _expert_ffn(p, buf, be: Policy, x_dtype):
    """(…, E, C, d) @ experts — grouped small GEMMs (the paper's habitat).

    The 3-D (per-shard) case routes each grouped product through
    ``api.batched_gemm``, so the per-group (C, K, N) problem gets the
    same input-aware, profile-refined treatment as the 2-D path (XLA
    einsum when the router declines pallas)."""
    wg = p["w_gate"].astype(x_dtype)
    wu = p["w_up"].astype(x_dtype)
    wd = p["w_down"].astype(x_dtype)
    if buf.ndim == 3 and be.pallas:
        from repro import api
        h = (jax.nn.silu(api.batched_gemm(buf, wg, policy=be))
             * api.batched_gemm(buf, wu, policy=be))
        return api.batched_gemm(h, wd, policy=be)
    eq = "ecd,edf->ecf" if buf.ndim == 3 else "gecd,edf->gecf"
    eq2 = "ecf,efd->ecd" if buf.ndim == 3 else "gecf,efd->gecd"
    h = jax.nn.silu(jnp.einsum(eq, buf, wg)) * jnp.einsum(eq, buf, wu)
    if buf.ndim == 4:
        h = constrain(h, "moe_group", "experts", None, "expert_mlp")
    out = jnp.einsum(eq2, h, wd)
    if buf.ndim == 4:
        out = constrain(out, "moe_group", "experts", None, None)
    return out


def moe(p: Dict, x, be: Policy, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux).

    §Perf iteration 2/4 (beyond-paper): dispatch and combine run PER DATA
    SHARD via a vmapped leading group axis sized to the data-parallel
    degree; the group axis is sharded over "data" so routing / sort /
    capacity / token gathers are embarrassingly parallel (zero cross-device
    token movement; capacity is per-shard, the standard per-device
    semantics).  The expert FFN itself runs OUTSIDE the vmap on the
    (G, E, C, d) buffer with explicit shardings: E over model (EP,
    moonshot) or the expert hidden dim over model (TP, mixtral)."""
    from repro.parallel.ctx import moe_shard_count
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    k = m.top_k
    G = moe_shard_count()
    if G <= 1 or T % G or (T // G) % 8:
        buf, meta, aux = _moe_dispatch(p["router"], x.reshape(T, d), cfg,
                                       _capacity(T, m))
        out_buf = _expert_ffn(p, buf, be, x.dtype)
        y = _moe_combine(out_buf, meta, T, k)
        return y.astype(x.dtype).reshape(B, S, d), aux
    T_loc = T // G
    C = _capacity(T_loc, m)
    xg = constrain(x.reshape(G, T_loc, d), "moe_group", None, None)
    buf, meta, aux = jax.vmap(
        lambda xs: _moe_dispatch(p["router"], xs, cfg, C))(xg)
    buf = constrain(buf, "moe_group", "experts", None, None)
    slot = constrain(meta[0], "moe_group", None)
    top_p = constrain(meta[1], "moe_group", None, None)
    out_buf = _expert_ffn(p, buf, be, x.dtype)
    yg = jax.vmap(lambda ob, sl, tp: _moe_combine(ob, (sl, tp), T_loc, k))(
        out_buf, slot, top_p)
    yg = constrain(yg, "moe_group", None, None)
    return yg.astype(x.dtype).reshape(B, S, d), aux.mean()
