"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

One parameter schema + three entry points (`forward_train`, `prefill`,
`decode`), all built on a remat'd ``lax.scan`` over stacked layer params
(compile time stays O(1) in depth — mandatory for the 81-layer zamba2 and
56-layer mixtral dry-runs).

Family wiring:
  dense / vlm   uniform [attn + mlp] blocks; attention pattern full /
                swa / local:global (per-layer lax.cond, both branches
                compiled once).
  moe           [attn + moe] blocks, aux loss accumulated in the carry.
  ssm           [mamba] blocks (attention-free).
  hybrid        [mamba] blocks + ONE shared [attn + mlp] block (zamba2
                style) applied every ``shared_attn_every`` layers; its
                params are closed over (true weight sharing), its KV cache
                is indexed per application.
VLM (internvl2) enters through ``prefix_embeds`` (the stubbed ViT
frontend); audio enc-dec lives in encdec.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.api import Policy
from repro.models.common import (assert_same_structure, mm, ninit,
                                 rmsnorm, stack_init, stack_specs)


# --------------------------------------------------------------------------
# Cache pytree.
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LMCache:
    pos: jax.Array                              # scalar int32: next position
    attn_k: Optional[jax.Array] = None          # (L, B, Hkv, W, hd)
    attn_v: Optional[jax.Array] = None
    conv: Optional[jax.Array] = None            # (L, B, K-1, ch)
    ssm: Optional[jax.Array] = None             # (L, B, nh, P, N)
    shared_k: Optional[jax.Array] = None        # (napps, B, Hkv, W, hd)
    shared_v: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.pos, self.attn_k, self.attn_v, self.conv, self.ssm,
                 self.shared_k, self.shared_v), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _n_shared_apps(cfg: ModelConfig) -> int:
    return -(cfg.n_layers // -cfg.shared_attn_every) \
        if cfg.shared_attn_every else 0


def cache_buffer_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: window-sized iff NO layer needs full context."""
    a = cfg.attn
    if cfg.family in ("ssm",):
        return 0
    if a.kind == "swa" and not cfg.shared_attn_every:
        return min(a.window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16, prefill_len: int = 0) -> LMCache:
    W = cache_buffer_len(cfg, seq_len)
    Hkv = cfg.n_kv_heads_padded
    hd = cfg.head_dim_ if cfg.n_heads else 0
    kw: Dict[str, Any] = {"pos": jnp.asarray(prefill_len, jnp.int32)}
    Ld = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        kw["attn_k"] = jnp.zeros((Ld, batch, Hkv, W, hd), dtype)
        kw["attn_v"] = jnp.zeros((Ld, batch, Hkv, W, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        ch = cfg.d_inner + 2 * s.d_state
        kw["conv"] = jnp.zeros((Ld, batch, s.d_conv - 1, ch), dtype)
        kw["ssm"] = jnp.zeros((Ld, batch, cfg.ssm_heads, s.head_dim,
                               s.d_state), jnp.float32)
    if cfg.shared_attn_every:
        na = _n_shared_apps(cfg)
        kw["shared_k"] = jnp.zeros((na, batch, Hkv, W, hd), dtype)
        kw["shared_v"] = jnp.zeros((na, batch, Hkv, W, hd), dtype)
    return LMCache(**kw)


# --------------------------------------------------------------------------
# Init / specs.
# --------------------------------------------------------------------------

def _norm_w(cfg: ModelConfig, dtype):
    return jnp.ones((cfg.d_model,), dtype) if cfg.parametric_norm else None


def _init_block(cfg: ModelConfig, dtype):
    def init(key):
        ks = jax.random.split(key, 2)
        if cfg.family in ("ssm", "hybrid"):
            return {"ln1": _norm_w(cfg, dtype),
                    "mixer": S.init_mamba(ks[0], cfg, dtype)}
        p = {"ln1": _norm_w(cfg, dtype),
             "attn": L.init_attention(ks[0], cfg, dtype),
             "ln2": _norm_w(cfg, dtype)}
        if cfg.family == "moe":
            p["moe"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg, dtype=dtype)
        return p
    return init


def _block_specs(cfg: ModelConfig):
    n = ("embed",) if cfg.parametric_norm else None
    if cfg.family in ("ssm", "hybrid"):
        return {"ln1": n, "mixer": S.mamba_specs(cfg)}
    sp = {"ln1": n, "attn": L.attention_specs(cfg), "ln2": n}
    if cfg.family == "moe":
        sp["moe"] = L.moe_specs(cfg)
    else:
        sp["mlp"] = L.mlp_specs(cfg)
    return sp


def init_lm(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d, Vp = cfg.d_model, cfg.vocab_padded
    params: Dict[str, Any] = {
        "embed": ninit(ks[0], (Vp, d), d ** -0.5, dtype),
        "blocks": stack_init(_init_block(cfg, dtype), ks[1], cfg.n_layers),
        "final_norm": _norm_w(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = ninit(ks[2], (d, Vp), 1.0 / math.sqrt(d), dtype)
    if cfg.shared_attn_every:
        kk = jax.random.split(ks[3], 2)
        params["shared"] = {
            "ln1": _norm_w(cfg, dtype),
            "attn": L.init_attention(kk[0], cfg, dtype),
            "ln2": _norm_w(cfg, dtype),
            "mlp": L.init_mlp(kk[1], cfg, dtype=dtype),
        }
    return params


def lm_specs(cfg: ModelConfig) -> Dict:
    n = ("embed",) if cfg.parametric_norm else None
    # embed/unembed shard ONLY the vocab dim (model axis): FSDP-sharding
    # the d_model dim forced a d-contracting logits matmul => a (B,S,V)
    # psum over data, and an 'involuntary full rematerialization' reshard
    # on the gather (§Perf iteration 3); vocab-only sharding removes both
    specs: Dict[str, Any] = {
        "embed": ("vocab", None),
        "blocks": stack_specs(_block_specs(cfg)),
        "final_norm": n,
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = (None, "vocab")
    if cfg.shared_attn_every:
        specs["shared"] = {"ln1": n, "attn": L.attention_specs(cfg),
                           "ln2": n, "mlp": L.mlp_specs(cfg)}
    return specs


# --------------------------------------------------------------------------
# Block application (shared by all modes).
# --------------------------------------------------------------------------

def _window_for_layer(cfg: ModelConfig, i):
    """Static-pattern helper; returns (needs_cond, window)."""
    a = cfg.attn
    if a.kind == "swa":
        return False, a.window
    if a.kind == "local_global":
        return True, a.window
    return False, None


def _apply_attn_block(p, x, be, cfg, i, *, kv=None, pos=None,
                      positions=None, paged_kv=None, return_kv=False):
    """attention (+cond on local/global) + mlp/moe. Returns
    (y, aux, new_kv_or_kv_pair)."""
    needs_cond, win = _window_for_layer(cfg, i)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)

    def run(window):
        return L.attention(p["attn"], h, be, cfg, causal=True, window=window,
                           positions=positions, kv_cache=kv, pos=pos,
                           paged_kv=paged_kv, return_kv=return_kv)

    if needs_cond:
        is_global = (i % (cfg.attn.local_ratio + 1)) == cfg.attn.local_ratio
        out = lax.cond(is_global, lambda: run(None), lambda: run(win))
    else:
        out = run(win)
    if kv is not None or paged_kv is not None or return_kv:
        attn_out, kv_out = out
    else:
        attn_out, kv_out = out, None
    x = x + attn_out
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = L.moe(p["moe"], h2, be, cfg)
    else:
        y = L.mlp(p["mlp"], h2, be)
    return x + y, aux, kv_out


def _apply_mamba_block(p, x, be, cfg, *, state=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if state is not None:
        y, new_state = S.mamba(p["mixer"], h, be, cfg, state=state)
        return x + y, new_state
    return x + S.mamba(p["mixer"], h, be, cfg), None


def _maybe_shared(params, x, be, cfg, i, *, shared_kv=None, pos=None,
                  positions=None, return_kv=False):
    """Hybrid: apply the shared attn block when i % every == 0."""
    if not cfg.shared_attn_every:
        return x, shared_kv
    sp = params["shared"]

    def apply(x):
        y, _, kv_out = _apply_attn_block(sp, x, be, cfg, i, kv=shared_kv,
                                         pos=pos, positions=positions,
                                         return_kv=return_kv)
        return y, kv_out

    def skip(x):
        if shared_kv is not None or return_kv:
            dummy = shared_kv
            if dummy is None:
                # return_kv path needs consistent shapes; build zeros
                B, Ssz, _ = x.shape
                hd, Hkv = cfg.head_dim_, cfg.n_kv_heads_padded
                z = jnp.zeros((B, Hkv, Ssz, hd), x.dtype)
                dummy = (z, z)
            return x, dummy
        return x, None

    return lax.cond(i % cfg.shared_attn_every == 0,
                    apply, skip, x)


# --------------------------------------------------------------------------
# Forward (train).
# --------------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens, be, prefix_embeds=None):
    from repro.parallel.ctx import constrain
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.compute_dtype), x],
                            axis=1)
    return constrain(x, "batch", None, None)


def _unembed(params, cfg, x, be: Policy):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return mm(x, w, be)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def forward_train(params: Dict, cfg: ModelConfig, be: Policy,
                  tokens: jax.Array,
                  prefix_embeds: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text) -> (logits (B, S_total, Vp), aux_loss)."""
    x = _embed_tokens(params, cfg, tokens, be, prefix_embeds)
    B, Stot, _ = x.shape
    positions = jnp.arange(Stot)
    idxs = jnp.arange(cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        def body(carry, xs):
            x = carry
            blk, i = xs
            x, _ = _maybe_shared(params, x, be, cfg, i, positions=positions)
            x, _ = _apply_mamba_block(blk, x, be, cfg)
            return x, None
        x, _ = lax.scan(_remat(body, cfg), x, (params["blocks"], idxs))
        aux = jnp.zeros((), jnp.float32)
    else:
        def body(carry, xs):
            x, aux = carry
            blk, i = xs
            x, a, _ = _apply_attn_block(blk, x, be, cfg, i,
                                        positions=positions)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(_remat(body, cfg),
                               (x, jnp.zeros((), jnp.float32)),
                               (params["blocks"], idxs))
        aux = aux / cfg.n_layers
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x, be), aux


# --------------------------------------------------------------------------
# Prefill / decode (serving).
# --------------------------------------------------------------------------

def _ring_layout(k, W: int):
    """Reorder the last W positions of k (B,H,S,hd) into ring-slot order."""
    Ssz = k.shape[2]
    if W >= Ssz:
        return k, Ssz
    slots = (Ssz - W) + jnp.mod(jnp.arange(W) - Ssz, W)
    return jnp.take(k, slots, axis=2), W


def _ring_pad(k, W: int, dtype):
    """Ring-layout + pad to exactly W slots (applied INSIDE the prefill
    layer scan so the stacked cache is (L,B,H,W,hd), never (L,B,H,S,hd) —
    for sliding-window archs at 32k that is a ~8x cache-stack saving)."""
    kr, have = _ring_layout(k, W)
    if have < W:
        kr = jnp.pad(kr, ((0, 0),) * 2 + ((0, W - have), (0, 0)))
    return kr.astype(dtype)


def prefill(params: Dict, cfg: ModelConfig, be: Policy, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, LMCache]:
    """Run the prompt, return (last-token logits (B, Vp), primed cache)."""
    x = _embed_tokens(params, cfg, tokens, be, prefix_embeds)
    B, Stot, _ = x.shape
    cache_len = cache_len or Stot
    cache = init_cache(cfg, B, cache_len, cfg.compute_dtype,
                       prefill_len=Stot)
    positions = jnp.arange(Stot)
    idxs = jnp.arange(cfg.n_layers)
    W = cache_buffer_len(cfg, cache_len)

    if cfg.family in ("ssm", "hybrid"):
        shared_ks, shared_vs = [], []

        def body(carry, xs):
            x = carry
            blk, i = xs
            x, skv = _maybe_shared(params, x, be, cfg, i,
                                   positions=positions, return_kv=True)
            if cfg.shared_attn_every:
                skv = (_ring_pad(skv[0], W, cfg.compute_dtype),
                       _ring_pad(skv[1], W, cfg.compute_dtype))
            # mamba with state capture
            h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
            y, st = _mamba_prefill(blk["mixer"], h, be, cfg)
            return x + y, (st, skv)
        x, (states, skvs) = lax.scan(body, x, (params["blocks"], idxs))
        conv_states, ssm_states = states
        cache.conv = conv_states
        cache.ssm = ssm_states
        if cfg.shared_attn_every:
            ks_, vs_ = skvs
            napps = _n_shared_apps(cfg)
            app_layers = jnp.arange(napps) * cfg.shared_attn_every
            cache.shared_k = ks_[app_layers]
            cache.shared_v = vs_[app_layers]
        aux = None
    else:
        def body(carry, xs):
            x = carry
            blk, i = xs
            x, _, kv = _apply_attn_block(blk, x, be, cfg, i,
                                         positions=positions, return_kv=True)
            return x, (_ring_pad(kv[0], W, cfg.compute_dtype),
                       _ring_pad(kv[1], W, cfg.compute_dtype))
        x, (ks_, vs_) = lax.scan(body, x, (params["blocks"], idxs))
        cache.attn_k = ks_
        cache.attn_v = vs_
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x, be)[:, 0]
    return logits, cache


def _mamba_prefill(p, h, be, cfg):
    """Mamba forward that also returns (conv_state, ssm_state)."""
    from repro.kernels import ref as R
    s = cfg.ssm
    B, Ssz, d = h.shape
    di, N, nh, P = cfg.d_inner, s.d_state, cfg.ssm_heads, s.head_dim
    z, xs, Bm, Cm, dt = S._project(p, h, cfg, be)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    A = -jnp.exp(p["A_log"])
    conv_out = jax.nn.silu(S._causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs_c = conv_out[..., :di].reshape(B, Ssz, nh, P)
    B_c = conv_out[..., di:di + N].reshape(B, Ssz, 1, N)
    C_c = conv_out[..., di + N:].reshape(B, Ssz, 1, N)
    dt_c = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, h_final = R.ref_ssd(xs_c, dt_c, A, B_c, C_c, D_skip=p["D"],
                           chunk=s.chunk, return_state=True)
    y = y.astype(jnp.float32).reshape(B, Ssz, di)
    y = rmsnorm((y.astype(h.dtype)
                 * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)),
                p["norm_w"], cfg.norm_eps)
    out = mm(y, p["out_proj"], be)
    Kc = s.d_conv - 1
    conv_state = conv_in[:, -Kc:].astype(h.dtype)
    if Ssz < Kc:
        conv_state = jnp.pad(conv_in, ((0, 0), (Kc - Ssz, 0), (0, 0))) \
            .astype(h.dtype)
    return out, (conv_state, h_final)


def decode(params: Dict, cfg: ModelConfig, be: Policy, tokens: jax.Array,
           cache: LMCache) -> Tuple[jax.Array, LMCache]:
    """One-token step. tokens: (B, 1). Returns (logits (B, Vp), cache)."""
    x = _embed_tokens(params, cfg, tokens, be)
    pos = cache.pos
    idxs = jnp.arange(cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        shared_kv_carry = (cache.shared_k, cache.shared_v)

        def body(carry, xs):
            x, sk, sv = carry
            blk, i, conv, ssm_h = xs
            if cfg.shared_attn_every:
                app = i // cfg.shared_attn_every

                def apply(x, sk, sv):
                    kv = (sk[app], sv[app])
                    y, _, kv_new = _apply_attn_block(
                        params["shared"], x, be, cfg, i, kv=kv, pos=pos)
                    sk = sk.at[app].set(kv_new[0])
                    sv = sv.at[app].set(kv_new[1])
                    return y, sk, sv

                x, sk, sv = lax.cond(i % cfg.shared_attn_every == 0,
                                     apply, lambda x, sk, sv: (x, sk, sv),
                                     x, sk, sv)
            x, st = _apply_mamba_block(blk, x, be, cfg, state=(conv, ssm_h))
            return (x, sk, sv), st
        (x, sk, sv), (conv_new, ssm_new) = lax.scan(
            body, (x, cache.shared_k, cache.shared_v),
            (params["blocks"], idxs, cache.conv, cache.ssm))
        cache = LMCache(pos=pos + 1, conv=conv_new, ssm=ssm_new,
                        shared_k=sk, shared_v=sv)
    else:
        def body(carry, xs):
            x = carry
            blk, i, kbuf, vbuf = xs
            x, _, kv = _apply_attn_block(blk, x, be, cfg, i,
                                         kv=(kbuf, vbuf), pos=pos)
            return x, kv
        x, (knew, vnew) = lax.scan(body, x, (params["blocks"], idxs,
                                             cache.attn_k, cache.attn_v))
        cache = LMCache(pos=pos + 1, attn_k=knew, attn_v=vnew)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x, be)[:, 0], cache


# --------------------------------------------------------------------------
# Paged KV (serving): block-pool cache + one step fn for chunked
# prefill AND slot decode.
# --------------------------------------------------------------------------

def paged_supported(cfg: ModelConfig) -> bool:
    """The paged path covers the pure-attention families; SSM/hybrid
    state and the shared-attn block keep using the wave engine."""
    return cfg.family in ("dense", "moe", "vlm") \
        and not cfg.shared_attn_every


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16):
    """Per-layer block pools, stacked: (L, P, Hkv, BS, hd) x2.  Block 0
    is the null sink (see repro.serve.paged) — zero-init keeps it
    finite for the masked reads inactive slots discard."""
    if not paged_supported(cfg):
        raise ValueError(f"paged KV unsupported for family={cfg.family} "
                         f"shared_attn_every={cfg.shared_attn_every}")
    Hkv, hd = cfg.n_kv_heads_padded, cfg.head_dim_
    shape = (cfg.n_layers, num_blocks, Hkv, block_size, hd)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def paged_step(params: Dict, cfg: ModelConfig, be: Policy,
               tokens: jax.Array, k_pools: jax.Array, v_pools: jax.Array,
               block_tables: jax.Array, pos_start: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One paged step: tokens (B, C) at absolute positions
    ``pos_start[b] + [0..C)``, K/V written through ``block_tables``
    (B, nmax), attention read back through the same tables.

    C > 1 is a prefill chunk (rows are causal within the chunk via the
    position mask); C == 1 is a slot-level decode step — one code path,
    two jit specialisations.  Returns (logits (B, C, Vp), k_pools,
    v_pools)."""
    x = _embed_tokens(params, cfg, tokens, be)
    B, C, _ = x.shape
    qpos = pos_start[:, None] + jnp.arange(C)[None, :]        # (B, C)
    idxs = jnp.arange(cfg.n_layers)

    def body(carry, xs):
        x = carry
        blk, i, kp, vp = xs
        x, _, kv = _apply_attn_block(
            blk, x, be, cfg, i, paged_kv=(kp, vp, block_tables, qpos))
        return x, kv
    x, (kps, vps) = lax.scan(body, x, (params["blocks"], idxs,
                                       k_pools, v_pools))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x, be), kps, vps
