"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

One parameter schema + three entry points (`forward_train`, `prefill`,
`decode`), all built on a remat'd ``lax.scan`` over stacked layer params
(compile time stays O(1) in depth — mandatory for the 81-layer zamba2 and
56-layer mixtral dry-runs).

Family wiring:
  dense / vlm   uniform [attn + mlp] blocks; attention pattern full /
                swa / local:global (per-layer lax.cond, both branches
                compiled once).
  moe           [attn + moe] blocks, aux loss accumulated in the carry.
  ssm           [mamba] blocks (attention-free).
  hybrid        [mamba] blocks + ONE shared [attn + mlp] block (zamba2
                style) applied every ``shared_attn_every`` layers; its
                params are closed over (true weight sharing), its KV cache
                is indexed per application.
VLM (internvl2) enters through ``prefix_embeds`` (the stubbed ViT
frontend); audio enc-dec lives in encdec.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.api import Policy
from repro.models.common import (assert_same_structure, mm, ninit,
                                 rmsnorm, stack_init, stack_specs)


# --------------------------------------------------------------------------
# Cache pytree.
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LMCache:
    pos: jax.Array                              # scalar int32: next position
    attn_k: Optional[jax.Array] = None          # (L, B, Hkv, W, hd)
    attn_v: Optional[jax.Array] = None
    conv: Optional[jax.Array] = None            # (L, B, K-1, ch)
    ssm: Optional[jax.Array] = None             # (L, B, nh, P, N)
    shared_k: Optional[jax.Array] = None        # (napps, B, Hkv, W, hd)
    shared_v: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.pos, self.attn_k, self.attn_v, self.conv, self.ssm,
                 self.shared_k, self.shared_v), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _n_shared_apps(cfg: ModelConfig) -> int:
    return -(cfg.n_layers // -cfg.shared_attn_every) \
        if cfg.shared_attn_every else 0


def cache_buffer_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: window-sized iff NO layer needs full context."""
    a = cfg.attn
    if cfg.family in ("ssm",):
        return 0
    if a.kind == "swa" and not cfg.shared_attn_every:
        return min(a.window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16, prefill_len: int = 0) -> LMCache:
    W = cache_buffer_len(cfg, seq_len)
    Hkv = cfg.n_kv_heads_padded
    hd = cfg.head_dim_ if cfg.n_heads else 0
    kw: Dict[str, Any] = {"pos": jnp.asarray(prefill_len, jnp.int32)}
    Ld = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        kw["attn_k"] = jnp.zeros((Ld, batch, Hkv, W, hd), dtype)
        kw["attn_v"] = jnp.zeros((Ld, batch, Hkv, W, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        ch = cfg.d_inner + 2 * s.d_state
        kw["conv"] = jnp.zeros((Ld, batch, s.d_conv - 1, ch), dtype)
        kw["ssm"] = jnp.zeros((Ld, batch, cfg.ssm_heads, s.head_dim,
                               s.d_state), jnp.float32)
    if cfg.shared_attn_every:
        na = _n_shared_apps(cfg)
        kw["shared_k"] = jnp.zeros((na, batch, Hkv, W, hd), dtype)
        kw["shared_v"] = jnp.zeros((na, batch, Hkv, W, hd), dtype)
    return LMCache(**kw)


# --------------------------------------------------------------------------
# Init / specs.
# --------------------------------------------------------------------------

def _norm_w(cfg: ModelConfig, dtype):
    return jnp.ones((cfg.d_model,), dtype) if cfg.parametric_norm else None


def _init_block(cfg: ModelConfig, dtype):
    def init(key):
        ks = jax.random.split(key, 2)
        if cfg.family in ("ssm", "hybrid"):
            return {"ln1": _norm_w(cfg, dtype),
                    "mixer": S.init_mamba(ks[0], cfg, dtype)}
        p = {"ln1": _norm_w(cfg, dtype),
             "attn": L.init_attention(ks[0], cfg, dtype),
             "ln2": _norm_w(cfg, dtype)}
        if cfg.family == "moe":
            p["moe"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg, dtype=dtype)
        return p
    return init


def _block_specs(cfg: ModelConfig):
    n = ("embed",) if cfg.parametric_norm else None
    if cfg.family in ("ssm", "hybrid"):
        return {"ln1": n, "mixer": S.mamba_specs(cfg)}
    sp = {"ln1": n, "attn": L.attention_specs(cfg), "ln2": n}
    if cfg.family == "moe":
        sp["moe"] = L.moe_specs(cfg)
    else:
        sp["mlp"] = L.mlp_specs(cfg)
    return sp


def init_lm(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d, Vp = cfg.d_model, cfg.vocab_padded
    params: Dict[str, Any] = {
        "embed": ninit(ks[0], (Vp, d), d ** -0.5, dtype),
        "blocks": stack_init(_init_block(cfg, dtype), ks[1], cfg.n_layers),
        "final_norm": _norm_w(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = ninit(ks[2], (d, Vp), 1.0 / math.sqrt(d), dtype)
    if cfg.shared_attn_every:
        kk = jax.random.split(ks[3], 2)
        params["shared"] = {
            "ln1": _norm_w(cfg, dtype),
            "attn": L.init_attention(kk[0], cfg, dtype),
            "ln2": _norm_w(cfg, dtype),
            "mlp": L.init_mlp(kk[1], cfg, dtype=dtype),
        }
    return params


def lm_specs(cfg: ModelConfig) -> Dict:
    n = ("embed",) if cfg.parametric_norm else None
    # embed/unembed shard ONLY the vocab dim (model axis): FSDP-sharding
    # the d_model dim forced a d-contracting logits matmul => a (B,S,V)
    # psum over data, and an 'involuntary full rematerialization' reshard
    # on the gather (§Perf iteration 3); vocab-only sharding removes both
    specs: Dict[str, Any] = {
        "embed": ("vocab", None),
        "blocks": stack_specs(_block_specs(cfg)),
        "final_norm": n,
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = (None, "vocab")
    if cfg.shared_attn_every:
        specs["shared"] = {"ln1": n, "attn": L.attention_specs(cfg),
                           "ln2": n, "mlp": L.mlp_specs(cfg)}
    return specs


# --------------------------------------------------------------------------
# Block application (shared by all modes).
# --------------------------------------------------------------------------

def _window_for_layer(cfg: ModelConfig, i):
    """Static-pattern helper; returns (needs_cond, window)."""
    a = cfg.attn
    if a.kind == "swa":
        return False, a.window
    if a.kind == "local_global":
        return True, a.window
    return False, None


def _apply_attn_block(p, x, be, cfg, i, *, kv=None, pos=None,
                      positions=None, paged_kv=None, return_kv=False):
    """attention (+cond on local/global) + mlp/moe. Returns
    (y, aux, new_kv_or_kv_pair)."""
    needs_cond, win = _window_for_layer(cfg, i)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)

    def run(window):
        return L.attention(p["attn"], h, be, cfg, causal=True, window=window,
                           positions=positions, kv_cache=kv, pos=pos,
                           paged_kv=paged_kv, return_kv=return_kv)

    if needs_cond:
        is_global = (i % (cfg.attn.local_ratio + 1)) == cfg.attn.local_ratio
        out = lax.cond(is_global, lambda: run(None), lambda: run(win))
    else:
        out = run(win)
    if kv is not None or paged_kv is not None or return_kv:
        attn_out, kv_out = out
    else:
        attn_out, kv_out = out, None
    x = x + attn_out
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = L.moe(p["moe"], h2, be, cfg)
    else:
        y = L.mlp(p["mlp"], h2, be)
    return x + y, aux, kv_out


def _apply_mamba_block(p, x, be, cfg, *, state=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if state is not None:
        y, new_state = S.mamba(p["mixer"], h, be, cfg, state=state)
        return x + y, new_state
    return x + S.mamba(p["mixer"], h, be, cfg), None


def _maybe_shared(params, x, be, cfg, i, *, shared_kv=None, pos=None,
                  positions=None, return_kv=False):
    """Hybrid: apply the shared attn block when i % every == 0."""
    if not cfg.shared_attn_every:
        return x, shared_kv
    sp = params["shared"]

    def apply(x):
        y, _, kv_out = _apply_attn_block(sp, x, be, cfg, i, kv=shared_kv,
                                         pos=pos, positions=positions,
                                         return_kv=return_kv)
        return y, kv_out

    def skip(x):
        if shared_kv is not None or return_kv:
            dummy = shared_kv
            if dummy is None:
                # return_kv path needs consistent shapes; build zeros
                B, Ssz, _ = x.shape
                hd, Hkv = cfg.head_dim_, cfg.n_kv_heads_padded
                z = jnp.zeros((B, Hkv, Ssz, hd), x.dtype)
                dummy = (z, z)
            return x, dummy
        return x, None

    return lax.cond(i % cfg.shared_attn_every == 0,
                    apply, skip, x)


# --------------------------------------------------------------------------
# Forward (train).
# --------------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens, be, prefix_embeds=None):
    from repro.parallel.ctx import constrain
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.compute_dtype), x],
                            axis=1)
    return constrain(x, "batch", None, None)


def _unembed(params, cfg, x, be: Policy):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return mm(x, w, be)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def forward_train(params: Dict, cfg: ModelConfig, be: Policy,
                  tokens: jax.Array,
                  prefix_embeds: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text) -> (logits (B, S_total, Vp), aux_loss)."""
    x = _embed_tokens(params, cfg, tokens, be, prefix_embeds)
    B, Stot, _ = x.shape
    positions = jnp.arange(Stot)
    idxs = jnp.arange(cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        def body(carry, xs):
            x = carry
            blk, i = xs
            x, _ = _maybe_shared(params, x, be, cfg, i, positions=positions)
            x, _ = _apply_mamba_block(blk, x, be, cfg)
            return x, None
        x, _ = lax.scan(_remat(body, cfg), x, (params["blocks"], idxs))
        aux = jnp.zeros((), jnp.float32)
    else:
        def body(carry, xs):
            x, aux = carry
            blk, i = xs
            x, a, _ = _apply_attn_block(blk, x, be, cfg, i,
                                        positions=positions)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(_remat(body, cfg),
                               (x, jnp.zeros((), jnp.float32)),
                               (params["blocks"], idxs))
        aux = aux / cfg.n_layers
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x, be), aux


# --------------------------------------------------------------------------
# Prefill / decode (serving).
# --------------------------------------------------------------------------

def _ring_layout(k, W: int):
    """Reorder the last W positions of k (B,H,S,hd) into ring-slot order."""
    Ssz = k.shape[2]
    if W >= Ssz:
        return k, Ssz
    slots = (Ssz - W) + jnp.mod(jnp.arange(W) - Ssz, W)
    return jnp.take(k, slots, axis=2), W


def _ring_pad(k, W: int, dtype):
    """Ring-layout + pad to exactly W slots (applied INSIDE the prefill
    layer scan so the stacked cache is (L,B,H,W,hd), never (L,B,H,S,hd) —
    for sliding-window archs at 32k that is a ~8x cache-stack saving)."""
    kr, have = _ring_layout(k, W)
    if have < W:
        kr = jnp.pad(kr, ((0, 0),) * 2 + ((0, W - have), (0, 0)))
    return kr.astype(dtype)


def prefill(params: Dict, cfg: ModelConfig, be: Policy, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, LMCache]:
    """Run the prompt, return (last-token logits (B, Vp), primed cache)."""
    x = _embed_tokens(params, cfg, tokens, be, prefix_embeds)
    B, Stot, _ = x.shape
    cache_len = cache_len or Stot
    cache = init_cache(cfg, B, cache_len, cfg.compute_dtype,
                       prefill_len=Stot)
    positions = jnp.arange(Stot)
    idxs = jnp.arange(cfg.n_layers)
    W = cache_buffer_len(cfg, cache_len)

    if cfg.family in ("ssm", "hybrid"):
        zero = S.init_paged_state(cfg, B, cfg.compute_dtype)

        def body(carry, xs):
            x = carry
            blk, i = xs
            x, skv = _maybe_shared(params, x, be, cfg, i,
                                   positions=positions, return_kv=True)
            if cfg.shared_attn_every:
                skv = (_ring_pad(skv[0], W, cfg.compute_dtype),
                       _ring_pad(skv[1], W, cfg.compute_dtype))
            # mamba over the whole prompt as ONE chunk of the serving
            # recurrence (ssm.paged_step from a zero carry) — the carry
            # left behind is bit-identical to any other chunking of the
            # same tokens, which is what makes the paged engine's
            # chunked prefill and recompute-resume exact against this
            # wave path at temperature 0
            h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
            y, st = S.paged_step(blk["mixer"], h, be, cfg, zero)
            return x + y, (st, skv)
        x, (states, skvs) = lax.scan(body, x, (params["blocks"], idxs))
        conv_states, ssm_states = states
        cache.conv = conv_states
        cache.ssm = ssm_states
        if cfg.shared_attn_every:
            ks_, vs_ = skvs
            napps = _n_shared_apps(cfg)
            app_layers = jnp.arange(napps) * cfg.shared_attn_every
            cache.shared_k = ks_[app_layers]
            cache.shared_v = vs_[app_layers]
        aux = None
    else:
        def body(carry, xs):
            x = carry
            blk, i = xs
            x, _, kv = _apply_attn_block(blk, x, be, cfg, i,
                                         positions=positions, return_kv=True)
            return x, (_ring_pad(kv[0], W, cfg.compute_dtype),
                       _ring_pad(kv[1], W, cfg.compute_dtype))
        x, (ks_, vs_) = lax.scan(body, x, (params["blocks"], idxs))
        cache.attn_k = ks_
        cache.attn_v = vs_
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x, be)[:, 0]
    return logits, cache


def decode(params: Dict, cfg: ModelConfig, be: Policy, tokens: jax.Array,
           cache: LMCache) -> Tuple[jax.Array, LMCache]:
    """One-token step. tokens: (B, 1). Returns (logits (B, Vp), cache)."""
    x = _embed_tokens(params, cfg, tokens, be)
    pos = cache.pos
    idxs = jnp.arange(cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        shared_kv_carry = (cache.shared_k, cache.shared_v)

        def body(carry, xs):
            x, sk, sv = carry
            blk, i, conv, ssm_h = xs
            if cfg.shared_attn_every:
                app = i // cfg.shared_attn_every

                def apply(x, sk, sv):
                    kv = (sk[app], sv[app])
                    y, _, kv_new = _apply_attn_block(
                        params["shared"], x, be, cfg, i, kv=kv, pos=pos)
                    sk = sk.at[app].set(kv_new[0])
                    sv = sv.at[app].set(kv_new[1])
                    return y, sk, sv

                x, sk, sv = lax.cond(i % cfg.shared_attn_every == 0,
                                     apply, lambda x, sk, sv: (x, sk, sv),
                                     x, sk, sv)
            x, st = _apply_mamba_block(blk, x, be, cfg, state=(conv, ssm_h))
            return (x, sk, sv), st
        (x, sk, sv), (conv_new, ssm_new) = lax.scan(
            body, (x, cache.shared_k, cache.shared_v),
            (params["blocks"], idxs, cache.conv, cache.ssm))
        cache = LMCache(pos=pos + 1, conv=conv_new, ssm=ssm_new,
                        shared_k=sk, shared_v=sv)
    else:
        def body(carry, xs):
            x = carry
            blk, i, kbuf, vbuf = xs
            x, _, kv = _apply_attn_block(blk, x, be, cfg, i,
                                         kv=(kbuf, vbuf), pos=pos)
            return x, kv
        x, (knew, vnew) = lax.scan(body, x, (params["blocks"], idxs,
                                             cache.attn_k, cache.attn_v))
        cache = LMCache(pos=pos + 1, attn_k=knew, attn_v=vnew)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x, be)[:, 0], cache


# --------------------------------------------------------------------------
# Paged serving (every family): block-pool KV + per-slot recurrent
# carries, one pytree threaded through chunked prefill AND slot decode.
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedState:
    """Device-side serving state for one PagedEngine instance.

    Attention K/V live in block pools indexed through block tables
    (token-proportional, block-granular, see repro.serve.paged);
    recurrent carries live in per-SLOT rows — fixed-size, allocated for
    the slot's lifetime, never per token.  Hybrid models add dedicated
    pools for the weight-shared attention block, one pool row per
    application.  Which request owns which slot row is host-side state
    (:class:`repro.serve.paged.SlotStateStore`)."""
    attn_k: Optional[jax.Array] = None    # (L, P, Hkv, BS, hd)
    attn_v: Optional[jax.Array] = None
    conv: Optional[jax.Array] = None      # (L, slots, K-1, ch)
    ssm: Optional[jax.Array] = None       # (L, slots, nh, Phd, N) f32
    shared_k: Optional[jax.Array] = None  # (napps, P, Hkv, BS, hd)
    shared_v: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.attn_k, self.attn_v, self.conv, self.ssm,
                 self.shared_k, self.shared_v), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_paged_state(cfg: ModelConfig, num_blocks: int, block_size: int,
                     slots: int, dtype=jnp.bfloat16) -> PagedState:
    """Zero serving state; block 0 of every pool is the null sink (see
    repro.serve.paged) — zero-init keeps it finite for the masked reads
    inactive slots discard.  Slot rows start zero and are re-zeroed
    inside the jit'd prefill step whenever a chunk starts at position 0
    (fresh admission or recompute-resume)."""
    Hkv, hd = cfg.n_kv_heads_padded, cfg.head_dim_
    pool = (num_blocks, Hkv, block_size, hd)
    kw: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm"):
        kw["attn_k"] = jnp.zeros((cfg.n_layers,) + pool, dtype)
        kw["attn_v"] = jnp.zeros((cfg.n_layers,) + pool, dtype)
    if cfg.family in ("ssm", "hybrid"):
        conv1, h1 = S.init_paged_state(cfg, slots, dtype)
        kw["conv"] = jnp.zeros((cfg.n_layers,) + conv1.shape, conv1.dtype)
        kw["ssm"] = jnp.zeros((cfg.n_layers,) + h1.shape, h1.dtype)
    if cfg.shared_attn_every:
        na = _n_shared_apps(cfg)
        kw["shared_k"] = jnp.zeros((na,) + pool, dtype)
        kw["shared_v"] = jnp.zeros((na,) + pool, dtype)
    return PagedState(**kw)


def _paged_core(params, cfg: ModelConfig, be: Policy, x, ps: PagedState,
                conv, ssm_h, block_tables, qpos, seg_len, active,
                decode_from=None):
    """Layer stack shared by paged prefill chunks and slot decode.
    ``conv``/``ssm_h`` are (L, B, ...) rows aligned with x's batch dim
    (callers slice/scatter the slot rows); K/V route through
    ``block_tables`` into the pools; ``decode_from`` (B,) marks the
    original decode boundary so recompute-resume chunks replay those
    rows with decode numerics (see layers.paged_attend).  Returns
    (logits, ps-with-new-pools, conv', ssm')."""
    idxs = jnp.arange(cfg.n_layers)
    if cfg.family in ("ssm", "hybrid"):
        def body(carry, xs):
            x, sk, sv = carry
            blk, i, cv, hh = xs
            if cfg.shared_attn_every:
                app = i // cfg.shared_attn_every

                def apply(x, sk, sv):
                    y, _, kv = _apply_attn_block(
                        params["shared"], x, be, cfg, i,
                        paged_kv=(sk[app], sv[app], block_tables, qpos,
                                  decode_from))
                    return y, sk.at[app].set(kv[0]), sv.at[app].set(kv[1])

                x, sk, sv = lax.cond(i % cfg.shared_attn_every == 0,
                                     apply, lambda x, sk, sv: (x, sk, sv),
                                     x, sk, sv)
            h = rmsnorm(x, blk["ln1"], cfg.norm_eps)
            y, (cv, hh) = S.paged_step(blk["mixer"], h, be, cfg, (cv, hh),
                                       seg_len=seg_len, active=active)
            return (x + y, sk, sv), (cv, hh)
        (x, sk, sv), (conv_new, ssm_new) = lax.scan(
            body, (x, ps.shared_k, ps.shared_v),
            (params["blocks"], idxs, conv, ssm_h))
        ps = dataclasses.replace(ps, shared_k=sk, shared_v=sv)
    else:
        def body(carry, xs):
            x = carry
            blk, i, kp, vp = xs
            x, _, kv = _apply_attn_block(
                blk, x, be, cfg, i,
                paged_kv=(kp, vp, block_tables, qpos, decode_from))
            return x, kv
        x, (kps, vps) = lax.scan(body, x, (params["blocks"], idxs,
                                           ps.attn_k, ps.attn_v))
        ps = dataclasses.replace(ps, attn_k=kps, attn_v=vps)
        conv_new = ssm_new = None
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x, be), ps, conv_new, ssm_new


def paged_prefill(params: Dict, cfg: ModelConfig, be: Policy,
                  tokens: jax.Array, ps: PagedState, block_tables,
                  pos_start, slot, seg_len,
                  n_prompt) -> Tuple[jax.Array, PagedState]:
    """One prefill chunk for ONE request occupying ``slot``: tokens
    (1, C) at absolute positions ``pos_start[0] + [0..C)`` (the tail
    past ``seg_len`` is padding and advances nothing), block_tables
    (1, nmax).  ``n_prompt`` is the request's prompt length: rows at
    positions >= n_prompt only exist on recompute-resume (they replay
    tokens the reference timeline generated by decode) and take the
    decode-path attention numerics so the rebuilt K/V and recurrent
    carries are bitwise what an unpreempted run would hold.

    When ``pos_start == 0`` — fresh admission OR recompute-resume after
    preemption — the slot's recurrent-carry rows are zero-reset inside
    this jit step, so state reset happens in automatic lockstep with
    the scheduler rewinding ``pos`` to 0; there is no separate host
    reset call to forget.  Returns (logits (1, C, Vp), ps)."""
    x = _embed_tokens(params, cfg, tokens, be)
    B, C, _ = x.shape
    qpos = pos_start[:, None] + jnp.arange(C)[None, :]        # (1, C)
    seg = jnp.full((B,), seg_len, jnp.int32)
    dfrom = jnp.full((B,), n_prompt, jnp.int32)
    conv = ssm_h = None
    if cfg.family in ("ssm", "hybrid"):
        conv = lax.dynamic_slice_in_dim(ps.conv, slot, 1, axis=1)
        ssm_h = lax.dynamic_slice_in_dim(ps.ssm, slot, 1, axis=1)
        fresh = pos_start[0] == 0
        conv = jnp.where(fresh, jnp.zeros_like(conv), conv)
        ssm_h = jnp.where(fresh, jnp.zeros_like(ssm_h), ssm_h)
    logits, ps, conv_new, ssm_new = _paged_core(
        params, cfg, be, x, ps, conv, ssm_h, block_tables, qpos, seg,
        None, dfrom)
    if conv_new is not None:
        ps = dataclasses.replace(
            ps,
            conv=lax.dynamic_update_slice_in_dim(ps.conv, conv_new,
                                                 slot, axis=1),
            ssm=lax.dynamic_update_slice_in_dim(ps.ssm, ssm_new,
                                                slot, axis=1))
    return logits, ps


def paged_decode(params: Dict, cfg: ModelConfig, be: Policy,
                 tokens: jax.Array, ps: PagedState, block_tables, pos,
                 active) -> Tuple[jax.Array, PagedState]:
    """One slot-level decode step over ALL slots: tokens (slots, 1),
    pos (slots,), active (slots,) bool.  Inactive rows (idle slots,
    slots mid-prefill) read/write the null block through their all-zero
    table row and keep their recurrent carries bitwise unchanged (see
    ssm.paged_step).  Returns (logits (slots, 1, Vp), ps)."""
    x = _embed_tokens(params, cfg, tokens, be)
    qpos = pos[:, None] + jnp.arange(x.shape[1])[None, :]     # (slots, 1)
    logits, ps, conv_new, ssm_new = _paged_core(
        params, cfg, be, x, ps, ps.conv, ps.ssm, block_tables, qpos,
        None, active)
    if conv_new is not None:
        ps = dataclasses.replace(ps, conv=conv_new, ssm=ssm_new)
    return logits, ps
