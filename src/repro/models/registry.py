"""Model registry: one uniform interface over all backbone families.

``Model`` bundles init/specs/apply closures so the launcher, dry-run,
trainer and server never branch on family."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                    # (key) -> params
    specs: Callable                   # () -> logical-axis tree
    forward_train: Callable           # (params, batch, be) -> (logits, aux)
    prefill: Callable                 # (params, batch, be) -> (logits, cache)
    decode: Callable                  # (params, batch, cache, be) -> (logits, cache)
    init_cache: Callable              # (batch, seq_len) -> cache
    # paged serving path (repro.serve.PagedEngine): block-pool KV plus
    # per-slot recurrent carries, so EVERY decoder-only family serves
    # paged; None only for encoder-decoder archs
    paged_prefill: Optional[Callable] = None
    # ^ (params, batch, ps, tables, pos0, slot, seg_len, n_prompt, be)
    #   -> (logits, ps)
    paged_decode: Optional[Callable] = None
    # ^ (params, batch, ps, tables, pos, active, be) -> (logits, ps)
    init_paged_state: Optional[Callable] = None
    # ^ (num_blocks, block_size, slots, dtype) -> lm.PagedState


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec" or cfg.family == "audio":
        def fwd(params, batch, be):
            return encdec.forward_train(params, cfg, be, batch["tokens"],
                                        batch["src_embeds"])

        def pf(params, batch, be, cache_len=None):
            return encdec.prefill(params, cfg, be, batch["tokens"],
                                  batch["src_embeds"], cache_len=cache_len)

        def dec(params, batch, cache, be):
            return encdec.decode(params, cfg, be, batch["tokens"], cache)

        def mk_cache(batch, seq_len, dtype=jnp.bfloat16, src_len=None):
            return encdec.init_cache(cfg, batch, seq_len,
                                     src_len or seq_len, dtype,
                                     prefill_len=seq_len)

        return Model(cfg, lambda key: encdec.init_encdec(key, cfg),
                     lambda: encdec.encdec_specs(cfg), fwd, pf, dec,
                     mk_cache)

    def fwd(params, batch, be):
        return lm.forward_train(params, cfg, be, batch["tokens"],
                                batch.get("prefix_embeds"))

    def pf(params, batch, be, cache_len=None):
        return lm.prefill(params, cfg, be, batch["tokens"],
                          batch.get("prefix_embeds"), cache_len=cache_len)

    def dec(params, batch, cache, be):
        return lm.decode(params, cfg, be, batch["tokens"], cache)

    def mk_cache(batch, seq_len, dtype=jnp.bfloat16, prefill_len=None):
        return lm.init_cache(cfg, batch, seq_len, dtype,
                             prefill_len=seq_len if prefill_len is None
                             else prefill_len)

    def ppf(params, batch, ps, tables, pos0, slot, seg_len, n_prompt, be):
        return lm.paged_prefill(params, cfg, be, batch["tokens"], ps,
                                tables, pos0, slot, seg_len, n_prompt)

    def pdec(params, batch, ps, tables, pos, active, be):
        return lm.paged_decode(params, cfg, be, batch["tokens"], ps,
                               tables, pos, active)

    def mk_ps(num_blocks, block_size, slots, dtype=jnp.bfloat16):
        return lm.init_paged_state(cfg, num_blocks, block_size, slots,
                                   dtype)

    return Model(cfg, lambda key: lm.init_lm(key, cfg),
                 lambda: lm.lm_specs(cfg), fwd, pf, dec, mk_cache,
                 paged_prefill=ppf, paged_decode=pdec,
                 init_paged_state=mk_ps)
