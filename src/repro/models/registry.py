"""Model registry: one uniform interface over all backbone families.

``Model`` bundles init/specs/apply closures so the launcher, dry-run,
trainer and server never branch on family."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                    # (key) -> params
    specs: Callable                   # () -> logical-axis tree
    forward_train: Callable           # (params, batch, be) -> (logits, aux)
    prefill: Callable                 # (params, batch, be) -> (logits, cache)
    decode: Callable                  # (params, batch, cache, be) -> (logits, cache)
    init_cache: Callable              # (batch, seq_len) -> cache
    # paged-KV serving path (repro.serve.PagedEngine); None when the
    # family needs recurrent state the block pool doesn't carry
    paged_step: Optional[Callable] = None   # (params, batch, pcache, be)
    init_paged_cache: Optional[Callable] = None  # (nblocks, bs, dtype)


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec" or cfg.family == "audio":
        def fwd(params, batch, be):
            return encdec.forward_train(params, cfg, be, batch["tokens"],
                                        batch["src_embeds"])

        def pf(params, batch, be, cache_len=None):
            return encdec.prefill(params, cfg, be, batch["tokens"],
                                  batch["src_embeds"], cache_len=cache_len)

        def dec(params, batch, cache, be):
            return encdec.decode(params, cfg, be, batch["tokens"], cache)

        def mk_cache(batch, seq_len, dtype=jnp.bfloat16, src_len=None):
            return encdec.init_cache(cfg, batch, seq_len,
                                     src_len or seq_len, dtype,
                                     prefill_len=seq_len)

        return Model(cfg, lambda key: encdec.init_encdec(key, cfg),
                     lambda: encdec.encdec_specs(cfg), fwd, pf, dec,
                     mk_cache)

    def fwd(params, batch, be):
        return lm.forward_train(params, cfg, be, batch["tokens"],
                                batch.get("prefix_embeds"))

    def pf(params, batch, be, cache_len=None):
        return lm.prefill(params, cfg, be, batch["tokens"],
                          batch.get("prefix_embeds"), cache_len=cache_len)

    def dec(params, batch, cache, be):
        return lm.decode(params, cfg, be, batch["tokens"], cache)

    def mk_cache(batch, seq_len, dtype=jnp.bfloat16, prefill_len=None):
        return lm.init_cache(cfg, batch, seq_len, dtype,
                             prefill_len=seq_len if prefill_len is None
                             else prefill_len)

    pstep = mk_paged = None
    if lm.paged_supported(cfg):
        def pstep(params, batch, pcache, be):
            k_pools, v_pools, tables, pos = pcache
            logits, k_pools, v_pools = lm.paged_step(
                params, cfg, be, batch["tokens"], k_pools, v_pools,
                tables, pos)
            return logits, (k_pools, v_pools)

        def mk_paged(num_blocks, block_size, dtype=jnp.bfloat16):
            return lm.init_paged_cache(cfg, num_blocks, block_size, dtype)

    return Model(cfg, lambda key: lm.init_lm(key, cfg),
                 lambda: lm.lm_specs(cfg), fwd, pf, dec, mk_cache,
                 paged_step=pstep, init_paged_cache=mk_paged)
