"""Mamba-2 block (SSD) — attention-free sequence mixing.

Train/prefill runs the chunked SSD (Pallas kernel or jnp oracle); decode
runs the O(1)-state recurrence.  The short causal conv is implemented as
``d_conv`` shifted adds (compiles everywhere, no conv primitive needed).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ref
from repro.api import Policy
from repro.models.common import mm, ninit, rmsnorm
from repro.parallel.ctx import constrain


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d, di, N = cfg.d_model, cfg.d_inner, s.d_state
    nh = cfg.ssm_heads
    ch = di + 2 * N                       # conv channels: x, B, C streams
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    dt = jnp.exp(jax.random.uniform(ks[4], (nh,), jnp.float32)
                 * (math.log(s.dt_max) - math.log(s.dt_min))
                 + math.log(s.dt_min))
    return {
        "in_proj": ninit(ks[0], (d, 2 * di + 2 * N + nh), sc, dtype),
        "conv_w": ninit(ks[1], (s.d_conv, ch), 0.2, dtype),
        "conv_b": jnp.zeros((ch,), dtype),
        "A_log": jnp.log(jnp.abs(
            jax.random.uniform(ks[2], (nh,), jnp.float32) * 15 + 1)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),   # softplus^{-1}(dt)
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": ninit(ks[3], (di, d),
                          1.0 / math.sqrt(di) / math.sqrt(2.0 * cfg.n_layers),
                          dtype),
    }


def mamba_specs(cfg: ModelConfig) -> Dict:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm_w": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x: (B,S,ch); w: (K,ch)."""
    K = w.shape[0]
    out = x * w[-1][None, None, :]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[K - 1 - i][None, None, :]
    return out + b[None, None, :]


def _conv_step(conv_state, x_t, w, b):
    """conv_state: (B, K-1, ch); x_t: (B, ch). Returns (state, y_t)."""
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,ch)
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return full[:, 1:], y.astype(x_t.dtype)


def _project(p, x, cfg: ModelConfig, be: Policy):
    s = cfg.ssm
    di, N, nh = cfg.d_inner, s.d_state, cfg.ssm_heads
    proj = mm(x, p["in_proj"], be)
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xs, Bm, Cm, dt


def mamba(p: Dict, x, be: Policy, cfg: ModelConfig,
          state: Optional[Tuple] = None):
    """Train/prefill path. x: (B, S, d) -> y (B, S, d).

    When ``state`` is given (decode, S==1) returns (y, new_state) where
    state = (conv_state, ssm_h)."""
    s = cfg.ssm
    B, S, d = x.shape
    di, N, nh, P = cfg.d_inner, s.d_state, cfg.ssm_heads, s.head_dim
    z, xs, Bm, Cm, dt = _project(p, x, cfg, be)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    A = -jnp.exp(p["A_log"])

    if state is not None:
        conv_state, h = state
        conv_state, conv_out = _conv_step(conv_state, conv_in[:, 0],
                                          p["conv_w"], p["conv_b"])
        conv_out = jax.nn.silu(conv_out)
        xs_c = conv_out[:, :di].reshape(B, nh, P)
        B_c = conv_out[:, di:di + N]
        C_c = conv_out[:, di + N:]
        dt_c = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                               + p["dt_bias"][None, :])
        h, y = ref.ref_ssd_decode_step(
            h, xs_c.astype(jnp.float32), dt_c, A,
            B_c.astype(jnp.float32), C_c.astype(jnp.float32))
        y = y + p["D"][None, :, None] * xs_c.astype(jnp.float32)
        y = y.reshape(B, 1, di).astype(x.dtype)
        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    p["norm_w"], cfg.norm_eps)
        return mm(y, p["out_proj"], be), (conv_state, h)

    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    conv_out = constrain(conv_out, "batch", None, "inner")
    xs_c = constrain(conv_out[..., :di].reshape(B, S, nh, P),
                     "batch", None, "ssm_heads", None)
    B_c = conv_out[..., di:di + N].reshape(B, S, 1, N)
    C_c = conv_out[..., di + N:].reshape(B, S, 1, N)
    dt_c = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt_c = constrain(dt_c, "batch", None, "ssm_heads")
    if be.pallas:
        from repro.kernels import ops
        y = ops.ssd_scan(xs_c, dt_c, A, B_c, C_c, chunk=s.chunk,
                         interpret=be.interpret)
        y = y.astype(jnp.float32) + p["D"][None, None, :, None] \
            * xs_c.astype(jnp.float32)
    else:
        y = ref.ref_ssd(xs_c, dt_c, A, B_c, C_c, D_skip=p["D"],
                        chunk=s.chunk).astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_w"], cfg.norm_eps)
    return mm(y, p["out_proj"], be)
