"""Mamba-2 block (SSD) — attention-free sequence mixing.

Training runs the chunked SSD (Pallas kernel or jnp oracle).  Every
serving path — wave prefill, wave decode, paged prefill chunks, paged
slot decode — runs ONE chunked recurrence with an explicit carry
(:func:`paged_step`), so the paged engine is token-identical to the
wave oracle by construction: the recurrent state after any token t is
the same bit pattern no matter how the tokens were chunked, which is
what makes recompute-resume after preemption exact at temperature 0.
The short causal conv is implemented as ``d_conv`` shifted adds
(compiles everywhere, no conv primitive needed).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ref
from repro.api import Policy
from repro.models.common import mm, ninit, rmsnorm
from repro.parallel.ctx import constrain


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d, di, N = cfg.d_model, cfg.d_inner, s.d_state
    nh = cfg.ssm_heads
    ch = di + 2 * N                       # conv channels: x, B, C streams
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    dt = jnp.exp(jax.random.uniform(ks[4], (nh,), jnp.float32)
                 * (math.log(s.dt_max) - math.log(s.dt_min))
                 + math.log(s.dt_min))
    return {
        "in_proj": ninit(ks[0], (d, 2 * di + 2 * N + nh), sc, dtype),
        "conv_w": ninit(ks[1], (s.d_conv, ch), 0.2, dtype),
        "conv_b": jnp.zeros((ch,), dtype),
        "A_log": jnp.log(jnp.abs(
            jax.random.uniform(ks[2], (nh,), jnp.float32) * 15 + 1)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),   # softplus^{-1}(dt)
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": ninit(ks[3], (di, d),
                          1.0 / math.sqrt(di) / math.sqrt(2.0 * cfg.n_layers),
                          dtype),
    }


def mamba_specs(cfg: ModelConfig) -> Dict:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm_w": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x: (B,S,ch); w: (K,ch)."""
    K = w.shape[0]
    out = x * w[-1][None, None, :]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[K - 1 - i][None, None, :]
    return out + b[None, None, :]


def _conv_chunk(conv_state, x, w, b):
    """Causal conv over a chunk with explicit left context.

    conv_state: (B, K-1, ch) — the last K-1 inputs before this chunk;
    x: (B, C, ch).  Returns per-position outputs (B, C, ch) in the
    serving numerics (f32 window einsum + bias, cast back)."""
    K = w.shape[0]
    C = x.shape[1]
    full = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    win = jnp.stack([full[:, i:i + C] for i in range(K)], axis=2)
    y = jnp.einsum("btkc,kc->btc", win.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x.dtype)


def _project(p, x, cfg: ModelConfig, be: Policy):
    s = cfg.ssm
    di, N, nh = cfg.d_inner, s.d_state, cfg.ssm_heads
    proj = mm(x, p["in_proj"], be)
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xs, Bm, Cm, dt


def mamba(p: Dict, x, be: Policy, cfg: ModelConfig,
          state: Optional[Tuple] = None):
    """Train/prefill path. x: (B, S, d) -> y (B, S, d).

    When ``state`` is given (decode, S==1) returns (y, new_state) where
    state = (conv_state, ssm_h)."""
    if state is not None:
        # decode (S == 1) is just a one-token chunk of the serving
        # recurrence — same code path as prefill chunks, exact resume
        return paged_step(p, x, be, cfg, state)

    s = cfg.ssm
    B, S, d = x.shape
    di, N, nh, P = cfg.d_inner, s.d_state, cfg.ssm_heads, s.head_dim
    z, xs, Bm, Cm, dt = _project(p, x, cfg, be)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    A = -jnp.exp(p["A_log"])

    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    conv_out = constrain(conv_out, "batch", None, "inner")
    xs_c = constrain(conv_out[..., :di].reshape(B, S, nh, P),
                     "batch", None, "ssm_heads", None)
    B_c = conv_out[..., di:di + N].reshape(B, S, 1, N)
    C_c = conv_out[..., di + N:].reshape(B, S, 1, N)
    dt_c = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt_c = constrain(dt_c, "batch", None, "ssm_heads")
    if be.pallas:
        from repro.kernels import ops
        y = ops.ssd_scan(xs_c, dt_c, A, B_c, C_c, chunk=s.chunk,
                         interpret=be.interpret)
        y = y.astype(jnp.float32) + p["D"][None, None, :, None] \
            * xs_c.astype(jnp.float32)
    else:
        y = ref.ref_ssd(xs_c, dt_c, A, B_c, C_c, D_skip=p["D"],
                        chunk=s.chunk).astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_w"], cfg.norm_eps)
    return mm(y, p["out_proj"], be)


# --------------------------------------------------------------------------
# Serving recurrence (paged engine + wave oracle share this path).
# --------------------------------------------------------------------------

def init_paged_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    """Zero recurrent carry for ONE mamba layer and ``batch`` rows (one
    row per engine slot): (conv carry (batch, d_conv-1, ch), SSM state
    (batch, nh, P, N) in f32).  Fixed-size per row — slot-lifetime, not
    token-proportional."""
    s = cfg.ssm
    ch = cfg.d_inner + 2 * s.d_state
    conv = jnp.zeros((batch, s.d_conv - 1, ch), dtype)
    h = jnp.zeros((batch, cfg.ssm_heads, s.head_dim, s.d_state),
                  jnp.float32)
    return conv, h


def paged_step(p: Dict, x, be: Policy, cfg: ModelConfig, state: Tuple,
               *, seg_len=None, active=None):
    """One mamba layer over a token chunk with an explicit carry — THE
    serving-path numerics.  x: (B, C, d); state = (conv_state
    (B, K-1, ch), h (B, nh, P, N)).

    ``seg_len`` (B,) marks how many of the C positions are real tokens
    (a prefill chunk's tail past the prompt is padding); ``active`` (B,)
    masks rows whose carry must not move (idle / mid-prefill slots
    sharing the decode batch).  Masked positions advance NEITHER the
    conv carry (the new carry is the last K-1 *valid* inputs) NOR the
    SSM state (dt is zeroed, so exp(dt*A) = 1 and the input term
    vanishes), and both are additionally re-selected through
    ``jnp.where`` so inactive rows are bitwise untouched.

    Each valid token undergoes exactly the ops of the one-token decode
    step, so chunking is invisible to the carry: prefill(prompt) then
    decode(k tokens) leaves the same state bits as one prefill over
    prompt+k — the property the recompute-resume parity tests pin down.
    Returns (y (B, C, d), (conv_state', h'))."""
    s = cfg.ssm
    B, C, _ = x.shape
    di, N, nh, P = cfg.d_inner, s.d_state, cfg.ssm_heads, s.head_dim
    conv_state, h = state
    if seg_len is None:
        seg_len = jnp.full((B,), C, jnp.int32)
    if active is None:
        active = jnp.ones((B,), bool)
    z, xs, Bm, Cm, dt = _project(p, x, cfg, be)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)          # (B, C, ch)
    A = -jnp.exp(p["A_log"])
    conv_out = jax.nn.silu(_conv_chunk(conv_state, conv_in,
                                       p["conv_w"], p["conv_b"]))
    xs_c = conv_out[..., :di].reshape(B, C, nh, P)
    B_c = conv_out[..., di:di + N]                            # (B, C, N)
    C_c = conv_out[..., di + N:]
    dt_c = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"][None, None, :])     # (B, C, nh)
    valid = (jnp.arange(C)[None, :] < seg_len[:, None]) \
        & active[:, None]                                     # (B, C)
    dt_m = jnp.where(valid[..., None], dt_c, 0.0)

    def step(hc, xs_t):
        xt, dtt, Bt, Ct = xs_t
        hc, y_t = ref.ref_ssd_decode_step(hc, xt, dtt, A, Bt, Ct)
        return hc, y_t

    h_new, ys = lax.scan(step, h, (
        xs_c.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt_m.transpose(1, 0, 2),
        B_c.transpose(1, 0, 2).astype(jnp.float32),
        C_c.transpose(1, 0, 2).astype(jnp.float32)))
    y = ys.transpose(1, 0, 2, 3)                              # (B, C, nh, P)
    y = y + p["D"][None, None, :, None] * xs_c.astype(jnp.float32)
    y = y.reshape(B, C, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_w"], cfg.norm_eps)
    out = mm(y, p["out_proj"], be)
    # conv carry: rows [seg_len, seg_len + K-1) of [carry ; chunk] are
    # the last K-1 inputs at or before the segment end
    Kc = s.d_conv - 1
    full = jnp.concatenate([conv_state.astype(conv_in.dtype), conv_in],
                           axis=1)
    idx = seg_len[:, None] + jnp.arange(Kc)[None, :]          # (B, Kc)
    conv_new = jnp.take_along_axis(full, idx[..., None], axis=1)
    conv_new = jnp.where(active[:, None, None],
                         conv_new.astype(conv_state.dtype), conv_state)
    h_new = jnp.where(active[:, None, None, None], h_new, h)
    return out, (conv_new, h_new)
