"""repro.obs — zero-dependency, process-local observability.

Every later perf PR reports through this layer, so it is deliberately
small and stdlib-only: a metric registry (:class:`Counter`,
:class:`Gauge`, log-bucket :class:`Histogram` with p50/p95/p99), a
:func:`span` context manager for wall-clock sections (which also emits a
``jax.profiler.TraceAnnotation`` so spans line up with device traces
when a profiler is active), and :func:`export_bench`, which writes a
schema'd ``BENCH_<name>.json`` at the repo root — the per-PR perf
trajectory ROADMAP asks for.

The hot-path consumer is ``api.Router.route``: every routing decision is
recorded into :data:`ROUTES`, a shape log keyed by the full call
signature ``(op, dtype, trans, dims, policy)``.  Because a decision is
deterministic given that key plus the active DeviceProfile, the log
doubles as a decision memo — a repeat shape is counted with one dict hit
and returns the cached :class:`~repro.api.Decision` without recomputing,
so routing with observability ON is *faster* than with it off, not just
<5% slower.  The aggregated view (counts per (op, dtype, size-class,
chosen backend/blocks)) is exactly the observed shape distribution the
traffic-aware tuning stage needs (Tillet's input-aware predictor trains
on it; see ROADMAP).

``REPRO_OBS=0`` in the environment disables everything: metric helpers
hand out shared null objects, :func:`span` skips the clock, and the
route log is bypassed with a single attribute check.
"""
from __future__ import annotations

import collections as _collections
import json
import math
import os
import pathlib
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY", "ROUTES",
    "TRACE", "counter", "gauge", "histogram", "span", "enabled",
    "set_enabled", "export_bench", "load_bench", "diff_bench",
    "report_str", "reset", "bench_root", "record_trajectory",
    "BENCH_SCHEMA_VERSION",
]

BENCH_SCHEMA_VERSION = 1

# Histogram bucket growth: bucket i covers [BASE**i, BASE**(i+1)) and
# reports its geometric midpoint, so the worst-case relative error of any
# percentile is sqrt(BASE) - 1 ~ 4.4% — tight enough to rank kernels and
# catch latency regressions, in O(log range) memory per metric.
_BASE = 2.0 ** 0.125
_LOG_BASE = math.log(_BASE)


def _env_enabled(value: Optional[str]) -> bool:
    """``REPRO_OBS`` parse: only explicit off values disable."""
    return (value or "1").strip().lower() not in ("0", "false", "off", "no")


_ENABLED = _env_enabled(os.environ.get("REPRO_OBS"))


def enabled() -> bool:
    """Whether observability is collecting (the ``REPRO_OBS`` switch)."""
    return _ENABLED


def set_enabled(on: bool) -> None:
    """Programmatic kill switch (tests, benchmarks).  Flips the registry,
    the route log, the flight recorder, and spans together so on/off
    comparisons are fair.  (The flight recorder can additionally be
    toggled alone via ``TRACE.set_enabled`` — the trace-overhead gates
    compare trace-ON vs trace-OFF with metrics ON both times.)"""
    global _ENABLED
    _ENABLED = bool(on)
    ROUTES.on = _ENABLED
    TRACE.on = _ENABLED


# --------------------------------------------------------------------------
# Metrics.
# --------------------------------------------------------------------------

class Counter:
    """Monotonic event count."""
    kind = "counter"
    __slots__ = ("n",)

    def __init__(self) -> None:
        self.n = 0

    def inc(self, k: int = 1) -> None:
        self.n += k

    @property
    def value(self) -> int:
        return self.n

    def to_json(self) -> dict:
        return {"type": "counter", "value": self.n}


class Gauge:
    """Last-write-wins instantaneous value."""
    kind = "gauge"
    __slots__ = ("v",)

    def __init__(self) -> None:
        self.v = 0.0

    def set(self, v: float) -> None:
        self.v = float(v)

    @property
    def value(self) -> float:
        return self.v

    def to_json(self) -> dict:
        return {"type": "gauge", "value": self.v}


class Histogram:
    """Log-bucketed distribution with exact count/sum/min/max.

    Non-positive samples land in a dedicated zero bucket (latencies and
    rates are positive; a 0 is usually a degenerate measurement worth
    keeping visible rather than dropping).
    """
    kind = "histogram"
    __slots__ = ("buckets", "zeros", "n", "total", "vmin", "vmax")

    def __init__(self) -> None:
        self.buckets: Dict[int, int] = {}
        self.zeros = 0
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= 0.0:
            self.zeros += 1
            return
        i = int(math.floor(math.log(v) / _LOG_BASE))
        b = self.buckets
        b[i] = b.get(i, 0) + 1

    @property
    def count(self) -> int:
        return self.n

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Value at percentile ``q`` in [0, 100], to bucket resolution.
        The extremes are exact: q<=0 returns the observed minimum and
        q>=100 the observed maximum (a ceil'd rank would otherwise pin
        q=0 to rank 1 and report ~the min *bucket*, not the min)."""
        if self.n == 0:
            return 0.0
        if q <= 0.0:
            return self.vmin
        if q >= 100.0:
            return self.vmax
        rank = max(1, math.ceil(q / 100.0 * self.n))
        seen = self.zeros
        if rank <= seen:
            return 0.0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if rank <= seen:
                # geometric midpoint of [BASE**i, BASE**(i+1)), clamped
                # to the exact observed extremes so tails never
                # overshoot reality
                rep = _BASE ** (i + 0.5)
                return min(max(rep, self.vmin), self.vmax)
        return self.vmax

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    def to_json(self) -> dict:
        return {"type": "histogram", "count": self.n,
                "sum": self.total, "mean": self.mean,
                "min": self.vmin if self.n else 0.0,
                "max": self.vmax if self.n else 0.0,
                "p50": self.p50, "p95": self.p95, "p99": self.p99}


class _Null:
    """Shared no-op metric handed out when observability is disabled."""
    kind = "null"
    __slots__ = ()
    n = 0
    v = 0.0
    value = 0
    count = 0
    total = 0.0
    mean = 0.0
    p50 = p95 = p99 = 0.0

    def inc(self, k: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def record(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return 0.0

    def to_json(self) -> dict:
        return {"type": "null"}


_NULL = _Null()


def _key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """Process-local metric store: one object per (name, labels)."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: Dict[str, Any]):
        if not _ENABLED:
            return _NULL
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(key, cls())
        if not isinstance(m, cls):
            raise TypeError(f"metric {key!r} is a {m.kind}, not "
                            f"{cls.kind}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def get(self, name: str, **labels):
        """Lookup without creating; None when never recorded."""
        return self._metrics.get(_key(name, labels))

    def collect(self, prefix: str = "") -> Dict[str, Any]:
        return {k: m for k, m in sorted(self._metrics.items())
                if k.startswith(prefix)}

    def snapshot(self) -> Dict[str, dict]:
        return {k: m.to_json() for k, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


REGISTRY = Registry()


def counter(name: str, **labels) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return REGISTRY.histogram(name, **labels)


def reset() -> None:
    """Clear every metric, the route log AND the flight recorder (tests,
    benchmark isolation)."""
    REGISTRY.reset()
    ROUTES.reset()
    TRACE.reset()


# --------------------------------------------------------------------------
# Spans.
# --------------------------------------------------------------------------

_span_stack = threading.local()
_trace_annotation = None     # resolved lazily; False when jax is absent


def _get_trace_annotation():
    global _trace_annotation
    if _trace_annotation is None:
        try:
            from jax.profiler import TraceAnnotation
            _trace_annotation = TraceAnnotation
        except Exception:  # pragma: no cover - jax is a core dep here
            _trace_annotation = False
    return _trace_annotation


class span:
    """Wall-clock section: ``with span("serve.prefill"): ...``

    Nested spans record under their dotted path ("a" inside "b" becomes
    ``span.b.a_us``), so a report shows where time went hierarchically.
    Each span also opens a ``jax.profiler.TraceAnnotation`` — free when
    no profiler is active, and the host-side section shows up alongside
    device events when one is.
    """
    __slots__ = ("name", "_t0", "_path", "_ann")

    def __init__(self, name: str) -> None:
        self.name = name
        self._t0 = 0.0
        self._path = ""
        self._ann = None

    def __enter__(self) -> "span":
        if not _ENABLED:
            return self
        stack = getattr(_span_stack, "names", None)
        if stack is None:
            stack = _span_stack.names = []
        stack.append(self.name)
        self._path = ".".join(stack)
        ta = _get_trace_annotation()
        if ta:
            self._ann = ta(self._path)
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        if not self._path:
            return
        dt_us = (time.perf_counter() - self._t0) * 1e6
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        stack = _span_stack.names
        if stack and stack[-1] == self.name:
            stack.pop()
        REGISTRY.histogram(f"span.{self._path}_us").record(dt_us)
        self._path = ""


# --------------------------------------------------------------------------
# The Router shape log (and decision memo).
# --------------------------------------------------------------------------

class RouteLog:
    """Every ``Router.route`` decision, keyed by the full call signature.

    A live entry is ``key -> [count, policy, gen, decision]`` where
    ``key = (op, letter, trans, dims, id(policy))``.  The holder keeps a
    strong reference to the policy, so the ``is`` check on a hit cannot
    alias a recycled ``id()``; ``gen`` is bumped by ``repro.tune.profile``
    whenever the active DeviceProfile changes, invalidating memoized
    decisions that might have consulted it.  Increments are plain dict
    ops — GIL-atomic enough for metrics (a lost count under a data race
    is acceptable; a torn value is not possible).

    When the table exceeds ``CAP`` distinct keys, live entries are folded
    into the aggregate histogram (per (op, dtype, trans, size-class,
    use_pallas, source, blocks)) and the memo restarts empty — counts are
    never lost, only the memoized Decisions.

    Locking: only the memo-HIT increment (``h[0] += 1`` inline in
    ``Router.route``) is lock-free — a count lost to that race is
    acceptable, a torn value impossible.  ``note`` (the miss path),
    compaction, snapshots and reset all take ``_lock``, so a compaction
    can never iterate a dict another thread is inserting into
    ("dict changed size during iteration") or drop a concurrent note.
    """
    CAP = 32768
    #: windowed() bucket width (seconds) and retention; see below.
    WINDOW_S = 1.0
    MAX_WINDOW_BUCKETS = 64

    def __init__(self) -> None:
        self.on = _ENABLED
        self.gen = 0
        self.hits: Dict[tuple, list] = {}
        self._agg: Dict[tuple, int] = {}
        self._lock = threading.Lock()
        # windowed-shape state: closed buckets (t_start, t_end, counts)
        # newest-first, plus the cumulative snapshot at the last close
        self._win = _collections.deque(maxlen=self.MAX_WINDOW_BUCKETS)
        self._win_prev: Dict[tuple, int] = {}
        self._win_t: Optional[float] = None

    # -- hot path (the .get/.note split lives inline in Router.route) ------

    def note(self, key: tuple, pol, decision) -> None:
        """First sighting of ``key``: memoize the decision, count = 1."""
        with self._lock:
            self.hits[key] = [1, pol, self.gen, decision]
            if len(self.hits) > self.CAP:
                self._compact_locked()

    def invalidate(self) -> None:
        """Active-profile changed: stale every memoized decision (counts
        survive; the next route per key recomputes and re-memoizes)."""
        self.gen += 1

    # -- aggregation (cold) ------------------------------------------------

    @staticmethod
    def _agg_key(key: tuple, d) -> tuple:
        op, letter, trans, dims = key[0], key[1], key[2], key[3]
        from repro.tune.classes import bucket_index  # lazy: cold path only
        if op == "matmul":
            m = 1
            for x in dims[:-2]:
                m *= int(x)
            mnk = (m, int(dims[-1]), int(dims[-2]))
        elif op in ("batched_gemm", "ragged_gemm"):
            # per-group problem (C, N, K) — the unit the Router priced
            mnk = (int(dims[1]), int(dims[3]), int(dims[2]))
        else:
            mnk = (int(dims[0]), int(dims[1]), int(dims[2]))
        cls = "-".join(str(bucket_index(max(1, x))) for x in mnk)
        return (op, letter, trans, cls, d.use_pallas, d.source, d.blocks)

    def _compact_locked(self) -> None:
        """Fold live entries into the aggregate; caller holds ``_lock``."""
        for key, h in self.hits.items():
            ak = self._agg_key(key, h[3])
            self._agg[ak] = self._agg.get(ak, 0) + h[0]
        self.hits.clear()

    def _compact(self) -> None:
        with self._lock:
            self._compact_locked()

    def histogram(self) -> Dict[tuple, int]:
        """Full-label counts: (op, dtype, trans, size-class, use_pallas,
        source, blocks) -> number of route() calls."""
        with self._lock:
            out = dict(self._agg)
            live = list(self.hits.items())
        for key, h in live:
            ak = self._agg_key(key, h[3])
            out[ak] = out.get(ak, 0) + h[0]
        return out

    def shape_counts(self) -> Dict[Tuple[str, str, str], int]:
        """The ROADMAP query: counts per (op, dtype, size-class)."""
        out: Dict[Tuple[str, str, str], int] = {}
        for (op, letter, _tr, cls, *_rest), n in self.histogram().items():
            k = (op, letter, cls)
            out[k] = out.get(k, 0) + n
        return out

    # -- windowed shape observation (the online-tuner feed) ----------------

    def windowed(self, n_buckets: int = 8, *,
                 bucket_s: Optional[float] = None,
                 decay: Optional[float] = None,
                 now: Optional[float] = None):
        """Time-bucketed shape counts — the input-distribution feed for
        online traffic-aware tuning (ROADMAP; Tillet's input-aware
        predictor trains on this, not on the all-time aggregate, so a
        traffic shift shows up within a bucket instead of being averaged
        away).

        Buckets are closed at *observation* time: each call diffs the
        cumulative :meth:`shape_counts` against the snapshot taken at
        the last bucket close, so recording stays entirely on the
        existing memo path (zero extra hot-path cost).  A caller polling
        every ``bucket_s`` seconds (the intended use) gets true
        fixed-width buckets; a slower poller gets one bucket spanning
        the gap — honest, never interpolated.

        Returns newest-first: ``[counts_open, counts_1, ...]`` — the
        open (still-filling) bucket, then up to ``n_buckets - 1`` closed
        ones; each ``counts`` maps ``(op, dtype, size-class) -> n``.
        With ``decay`` in (0, 1], the buckets are instead folded into
        ONE dict of exponentially-decayed weights (bucket *i* weighted
        ``decay**i``) — the sweep-weighting form the tuner consumes
        directly.  ``now`` injects a clock for tests.
        """
        if n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")
        width = bucket_s or self.WINDOW_S
        t = time.monotonic() if now is None else now
        cur = self.shape_counts()
        with self._lock:
            if self._win_t is None:
                self._win_t = t
            elif t - self._win_t >= width:
                delta = {k: cur[k] - self._win_prev.get(k, 0)
                         for k in cur
                         if cur[k] > self._win_prev.get(k, 0)}
                self._win.appendleft((self._win_t, t, delta))
                self._win_prev = cur
                self._win_t = t
            open_bucket = {k: cur[k] - self._win_prev.get(k, 0)
                           for k in cur
                           if cur[k] > self._win_prev.get(k, 0)}
            buckets = [open_bucket] + [c for (_a, _b, c) in
                                       list(self._win)[:n_buckets - 1]]
        if decay is None:
            return buckets
        if not 0.0 < decay <= 1.0:
            raise ValueError("decay must be in (0, 1]")
        folded: Dict[Tuple[str, str, str], float] = {}
        for i, counts in enumerate(buckets):
            w = decay ** i
            for k, n in counts.items():
                folded[k] = folded.get(k, 0.0) + w * n
        return folded

    @property
    def total(self) -> int:
        return sum(self.histogram().values())

    def snapshot(self) -> List[dict]:
        rows = []
        for (op, letter, trans, cls, pallas, source,
             blocks), n in sorted(self.histogram().items(),
                                  key=lambda kv: (-kv[1], kv[0])):
            rows.append({"op": op, "dtype": letter, "trans": trans,
                         "size_class": cls, "use_pallas": pallas,
                         "source": source,
                         "blocks": list(blocks) if blocks else None,
                         "count": n})
        return rows

    def reset(self) -> None:
        with self._lock:
            self.hits.clear()
            self._agg.clear()
            self._win.clear()
            self._win_prev = {}
            self._win_t = None
            self.gen += 1


ROUTES = RouteLog()


# --------------------------------------------------------------------------
# The flight recorder (repro.obs.trace) — event ring + Perfetto export.
# --------------------------------------------------------------------------

from repro.obs import trace  # noqa: E402  (needs nothing above at import)

#: The process-global per-request event ring (see :mod:`repro.obs.trace`).
#: Obeys ``REPRO_OBS`` like every other collector; ``REPRO_TRACE=0``
#: additionally disables just the recorder.
TRACE = trace.TRACE
TRACE.on = TRACE.on and _ENABLED


# --------------------------------------------------------------------------
# BENCH_<name>.json export.
# --------------------------------------------------------------------------

def bench_root() -> pathlib.Path:
    """Where BENCH files land: ``REPRO_BENCH_DIR`` or the repo root
    (three levels above this file — src/repro/obs)."""
    env = os.environ.get("REPRO_BENCH_DIR")
    if env:
        return pathlib.Path(env)
    return pathlib.Path(__file__).resolve().parents[3]


def export_bench(name: str, meta: Optional[dict] = None, *,
                 root: Optional[os.PathLike] = None) -> pathlib.Path:
    """Write the current registry + route log as ``BENCH_<name>.json``.

    The file is the repo's perf-trajectory record: schema-versioned,
    sorted keys, one file per benchmark name so successive PRs diff
    cleanly (``python -m repro.obs diff old.json new.json``).  An
    existing file's ``trajectory`` list (the append-only per-PR history
    written by :func:`record_trajectory`) is carried over, so a fresh
    export refreshes the snapshot without erasing the history."""
    doc = {
        "bench": name,
        "schema": BENCH_SCHEMA_VERSION,
        "created_unix": time.time(),
        "meta": dict(meta or {}),
        "metrics": REGISTRY.snapshot(),
        "router": ROUTES.snapshot(),
    }
    path = pathlib.Path(root) if root else bench_root()
    path.mkdir(parents=True, exist_ok=True)
    out = path / f"BENCH_{name}.json"
    if out.exists():
        try:
            prev = json.loads(out.read_text()).get("trajectory")
            if prev:
                doc["trajectory"] = prev
        except (OSError, ValueError):
            pass        # corrupt old file: overwrite, don't crash the bench
    tmp = out.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    tmp.replace(out)
    return out


def record_trajectory(name: str, entry: dict, *,
                      root: Optional[os.PathLike] = None) -> pathlib.Path:
    """Append one per-PR row to ``BENCH_<name>.json``'s ``trajectory``.

    The trajectory is the longitudinal record ROADMAP asks for: each
    ``benchmarks/run.py --record`` run appends a small dict of headline
    numbers (tokens/s, latency percentiles) stamped with the current
    commit when available, so regressions are visible across PRs, not
    just against the latest snapshot.  Creates a skeleton doc when the
    BENCH file does not exist yet."""
    path = pathlib.Path(root) if root else bench_root()
    path.mkdir(parents=True, exist_ok=True)
    out = path / f"BENCH_{name}.json"
    try:
        doc = json.loads(out.read_text())
    except (OSError, ValueError):
        doc = {"bench": name, "schema": BENCH_SCHEMA_VERSION,
               "created_unix": time.time(), "meta": {}, "metrics": {},
               "router": []}
    row = {"recorded_unix": time.time()}
    commit = _git_head()
    if commit:
        row["commit"] = commit
    row.update(entry)
    doc.setdefault("trajectory", []).append(row)
    tmp = out.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    tmp.replace(out)
    return out


_GIT_HEAD_CACHE: Optional[Tuple[Optional[str]]] = None


def _git_head() -> Optional[str]:
    """Short commit hash of the repo containing this file, or None.
    Memoized per process — HEAD cannot move under a running benchmark,
    and ``record_trajectory`` may be called once per suite."""
    global _GIT_HEAD_CACHE
    if _GIT_HEAD_CACHE is None:
        import subprocess
        try:
            head = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=pathlib.Path(__file__).resolve().parent, timeout=5,
                capture_output=True, text=True, check=True).stdout.strip()
        except Exception:
            head = None
        _GIT_HEAD_CACHE = (head,)
    return _GIT_HEAD_CACHE[0]


def load_bench(path: os.PathLike) -> dict:
    doc = json.loads(pathlib.Path(path).read_text())
    schema = int(doc.get("schema", -1))
    if schema != BENCH_SCHEMA_VERSION:
        raise ValueError(f"{path}: BENCH schema {schema} != supported "
                         f"{BENCH_SCHEMA_VERSION}")
    return doc


def _scalar_metrics(doc: dict) -> Dict[str, float]:
    """Flatten a BENCH doc to comparable scalars (counter/gauge values,
    histogram count/mean/p50/p95/p99)."""
    out: Dict[str, float] = {}
    for key, m in doc.get("metrics", {}).items():
        t = m.get("type")
        if t in ("counter", "gauge"):
            out[key] = float(m["value"])
        elif t == "histogram":
            for f in ("count", "mean", "p50", "p95", "p99"):
                out[f"{key}.{f}"] = float(m[f])
    return out


def diff_bench(a: dict, b: dict) -> List[Tuple[str, Optional[float],
                                               Optional[float],
                                               Optional[float]]]:
    """Rows of (metric, old, new, pct_change); None marks one-sided keys."""
    am, bm = _scalar_metrics(a), _scalar_metrics(b)
    rows: List[Tuple[str, Optional[float], Optional[float],
                     Optional[float]]] = []
    for key in sorted(set(am) | set(bm)):
        old, new = am.get(key), bm.get(key)
        pct = None
        if old is not None and new is not None and old != 0:
            pct = (new - old) / abs(old) * 100.0
        rows.append((key, old, new, pct))
    return rows


def report_str() -> str:
    """Human-readable dump of the live registry + route histogram."""
    lines = ["== repro.obs report =="]
    metrics = REGISTRY.collect()
    if not metrics and not ROUTES.total:
        lines.append("(empty — nothing recorded, or REPRO_OBS=0)")
    for key, m in metrics.items():
        if m.kind == "counter":
            lines.append(f"  {key:<44s} {m.value}")
        elif m.kind == "gauge":
            lines.append(f"  {key:<44s} {m.value:.6g}")
        else:
            lines.append(
                f"  {key:<44s} n={m.count} mean={m.mean:.1f} "
                f"p50={m.p50:.1f} p95={m.p95:.1f} p99={m.p99:.1f}")
    rows = ROUTES.snapshot()
    if rows:
        lines.append(f"  -- router shape histogram "
                     f"({ROUTES.total} decisions) --")
        for r in rows[:20]:
            lines.append(
                f"  {r['op']:<13s} {r['dtype']}/{r['trans']} "
                f"class={r['size_class']:<10s} "
                f"{'pallas' if r['use_pallas'] else 'xla':<6s} "
                f"{r['source']:<10s} x{r['count']}")
        if len(rows) > 20:
            lines.append(f"  ... {len(rows) - 20} more rows")
    return "\n".join(lines)
