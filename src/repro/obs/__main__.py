"""CLI for the observability layer.

    python -m repro.obs                     # summarize BENCH_*.json files
    python -m repro.obs ls                  # same ("list" also works)
    python -m repro.obs show BENCH_x.json   # pretty-print one BENCH file
    python -m repro.obs diff OLD NEW        # metric deltas between two
    python -m repro.obs report              # live registry of this process
    python -m repro.obs trace OUT.json      # live flight recorder -> Perfetto
    python -m repro.obs trace IN OUT.json   # re-export a --trace dump

``diff`` is the per-PR perf-trajectory tool: run a benchmark on main,
run it on your branch, diff the two BENCH files.  Exits 0 always — the
numbers are for humans; regression gates belong in the benchmarks
themselves.

``trace`` writes a Chrome-trace-event JSON (open in
https://ui.perfetto.dev or ``chrome://tracing``): slots as tracks,
requests as flow-connected queued→prefill→decode slices.  With one
path it dumps THIS process's live ring (useful after an in-process
serve); with two it re-derives the view from a file previously written
by ``benchmarks/serve_stream.py --trace`` / ``launch.serve --trace``
(raw events ride inside the file), printing the per-request derived
metrics either way.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro import obs
from repro.obs import trace as trace_mod


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}" if isinstance(v, float) else str(v)


def _show(path: pathlib.Path) -> None:
    doc = obs.load_bench(path)
    print(f"== {path.name} (bench={doc['bench']}, "
          f"schema={doc['schema']}) ==")
    meta = doc.get("meta", {})
    if meta:
        print("  meta: " + ", ".join(f"{k}={v}" for k, v in
                                     sorted(meta.items())))
    for key, val in sorted(obs._scalar_metrics(doc).items()):
        print(f"  {key:<52s} {_fmt(val)}")
    rows = doc.get("router", [])
    if rows:
        print(f"  -- router shape histogram ({len(rows)} classes) --")
        for r in rows[:15]:
            print(f"  {r['op']:<13s} {r['dtype']}/{r['trans']} "
                  f"class={r['size_class']:<10s} {r['source']:<10s} "
                  f"x{r['count']}")


def _diff(old: pathlib.Path, new: pathlib.Path) -> None:
    a, b = obs.load_bench(old), obs.load_bench(new)
    print(f"== diff {old.name} -> {new.name} ==")
    print(f"{'metric':<52s} {'old':>12s} {'new':>12s} {'change':>9s}")
    for key, va, vb, pct in obs.diff_bench(a, b):
        change = f"{pct:+.1f}%" if pct is not None else "-"
        print(f"{key:<52s} {_fmt(va):>12s} {_fmt(vb):>12s} {change:>9s}")


_TRACE_COLS = ("queue_wait_us", "ttft_wait_us", "ttft_prefill_us",
               "decode_stall_us", "preemptions", "n_out")


def _print_per_request(per: dict) -> None:
    if not per:
        print("(no request events in the trace)")
        return
    print(f"{'rid':>5s} " + " ".join(f"{c:>16s}" for c in _TRACE_COLS))
    for rid in sorted(per):
        r = per[rid]
        print(f"{rid:>5d} " + " ".join(
            f"{_fmt(r.get(c)):>16s}" for c in _TRACE_COLS))


def _trace(files) -> int:
    if len(files) == 1:                      # live ring of THIS process
        events = obs.TRACE.snapshot()
        out = pathlib.Path(files[0])
        if not events:
            print("live flight recorder is empty (tracing happens in the "
                  "serving process; convert a --trace dump with: "
                  "python -m repro.obs trace IN.json OUT.json)")
    elif len(files) == 2:                    # re-export a --trace dump
        events = trace_mod.load_events(files[0])
        out = pathlib.Path(files[1])
    else:
        return -1
    path = trace_mod.write_trace(out, events)
    per = trace_mod.per_request(events)
    _print_per_request(per)
    print(f"wrote {path} ({len(events)} events; open in "
          f"https://ui.perfetto.dev)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("cmd", nargs="?", default="list",
                    choices=["list", "ls", "show", "diff", "report",
                             "trace"])
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json path(s); for trace: [IN] OUT")
    args = ap.parse_args(argv)

    if args.cmd == "report":
        print(obs.report_str())
        return 0
    if args.cmd == "show":
        if len(args.files) != 1:
            ap.error("show takes exactly one BENCH file")
        _show(pathlib.Path(args.files[0]))
        return 0
    if args.cmd == "diff":
        if len(args.files) != 2:
            ap.error("diff takes exactly two BENCH files: OLD NEW")
        _diff(pathlib.Path(args.files[0]), pathlib.Path(args.files[1]))
        return 0
    if args.cmd == "trace":
        if _trace(args.files) != 0:
            ap.error("trace takes OUT.json (live ring) or IN.json OUT.json "
                     "(re-export a dump)")
        return 0
    found = sorted(obs.bench_root().glob("BENCH_*.json"))
    if not found:
        print(f"no BENCH_*.json under {obs.bench_root()} — run "
              f"`python benchmarks/serve_stream.py` to produce one")
        return 0
    for p in found:
        _show(p)
    return 0


if __name__ == "__main__":
    sys.exit(main())
