"""CLI for the observability layer.

    python -m repro.obs                     # summarize BENCH_*.json files
    python -m repro.obs show BENCH_x.json   # pretty-print one BENCH file
    python -m repro.obs diff OLD NEW        # metric deltas between two
    python -m repro.obs report              # live registry of this process

``diff`` is the per-PR perf-trajectory tool: run a benchmark on main,
run it on your branch, diff the two BENCH files.  Exits 0 always — the
numbers are for humans; regression gates belong in the benchmarks
themselves.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro import obs


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}" if isinstance(v, float) else str(v)


def _show(path: pathlib.Path) -> None:
    doc = obs.load_bench(path)
    print(f"== {path.name} (bench={doc['bench']}, "
          f"schema={doc['schema']}) ==")
    meta = doc.get("meta", {})
    if meta:
        print("  meta: " + ", ".join(f"{k}={v}" for k, v in
                                     sorted(meta.items())))
    for key, val in sorted(obs._scalar_metrics(doc).items()):
        print(f"  {key:<52s} {_fmt(val)}")
    rows = doc.get("router", [])
    if rows:
        print(f"  -- router shape histogram ({len(rows)} classes) --")
        for r in rows[:15]:
            print(f"  {r['op']:<13s} {r['dtype']}/{r['trans']} "
                  f"class={r['size_class']:<10s} {r['source']:<10s} "
                  f"x{r['count']}")


def _diff(old: pathlib.Path, new: pathlib.Path) -> None:
    a, b = obs.load_bench(old), obs.load_bench(new)
    print(f"== diff {old.name} -> {new.name} ==")
    print(f"{'metric':<52s} {'old':>12s} {'new':>12s} {'change':>9s}")
    for key, va, vb, pct in obs.diff_bench(a, b):
        change = f"{pct:+.1f}%" if pct is not None else "-"
        print(f"{key:<52s} {_fmt(va):>12s} {_fmt(vb):>12s} {change:>9s}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("cmd", nargs="?", default="list",
                    choices=["list", "show", "diff", "report"])
    ap.add_argument("files", nargs="*", help="BENCH_*.json path(s)")
    args = ap.parse_args(argv)

    if args.cmd == "report":
        print(obs.report_str())
        return 0
    if args.cmd == "show":
        if len(args.files) != 1:
            ap.error("show takes exactly one BENCH file")
        _show(pathlib.Path(args.files[0]))
        return 0
    if args.cmd == "diff":
        if len(args.files) != 2:
            ap.error("diff takes exactly two BENCH files: OLD NEW")
        _diff(pathlib.Path(args.files[0]), pathlib.Path(args.files[1]))
        return 0
    found = sorted(obs.bench_root().glob("BENCH_*.json"))
    if not found:
        print(f"no BENCH_*.json under {obs.bench_root()} — run "
              f"`python benchmarks/serve_stream.py` to produce one")
        return 0
    for p in found:
        _show(p)
    return 0


if __name__ == "__main__":
    sys.exit(main())
