"""repro.obs.trace — per-request flight recorder for the serving stack.

The aggregate histograms in :mod:`repro.obs` answer *how slow* — this
module answers *why*: a bounded ring-buffer :class:`EventLog` records
typed, timestamped events from the paged serving engine (admission,
prefill chunks, first token, sampled decode ticks, preemption/resume,
finish, block eviction), the Router's memo-miss path and the tuner's
profile swaps.  Three consumers sit on top:

* :func:`per_request` — a reducer deriving per-request queue-wait, the
  TTFT breakdown (wait vs prefill), decode-stall time (preempt→resume
  gaps after the first token) and preemption counts; :func:`observe`
  folds those into ``REGISTRY`` histograms so they land in the BENCH
  export next to the aggregates.
* :func:`perfetto` / :func:`write_trace` — a Chrome-trace-event JSON
  export (loadable in Perfetto / ``chrome://tracing``): slots render as
  tracks, each request as flow-connected queued→prefill→decode slices,
  so a scheduling pathology (a request parked in the queue, a preempt
  ping-pong) is *visible* instead of inferred from a p99.
* the raw event list itself, embedded in the export under
  ``reproTrace`` so ``python -m repro.obs trace IN OUT`` can re-derive
  both views offline.

Recording discipline: events are only emitted from host-side scheduling
code (never inside jit), appends are single ``deque.append`` calls
(GIL-atomic; drop-oldest is the deque's ``maxlen``), timestamps are
``time.perf_counter()`` (monotonic), and the whole layer obeys the
``REPRO_OBS`` kill switch plus its own ``REPRO_TRACE=0`` override.
High-frequency decode steps are sampled (``PagedEngine.TICK_SAMPLE``)
so a long decode cannot wash the interesting transitions out of the
ring.
"""
from __future__ import annotations

import collections
import json
import os
import pathlib
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "EVENT_TYPES", "Event", "EventLog", "TRACE", "emit", "per_request",
    "observe", "summary", "perfetto", "write_trace", "load_events",
    "TRACE_SCHEMA_VERSION",
]

TRACE_SCHEMA_VERSION = 1

#: The closed event taxonomy (DESIGN.md §Tracing).  ``emit`` rejects
#: anything else so a typo'd event name fails at the emission site, not
#: silently in every consumer.
EVENT_TYPES = frozenset((
    "REQ_ARRIVE",     # engine.submit: rid, (prompt_len, max_new)
    "ADMIT",          # sched.admit, first admission: rid, slot
    "RESUME",         # sched.admit, re-admission after preempt: rid, slot
    "PREFILL_CHUNK",  # engine: rid, slot, (pos0, n_tokens), dur_us
    "FIRST_TOKEN",    # engine: rid, slot
    "DECODE_TICK",    # engine, sampled: (step_idx, n_decoding)
    "PREEMPT",        # sched.preempt: rid, slot
    "FINISH",         # engine._finish: rid, slot, n_out
    "EVICT",          # paged.CacheMap.release: rid, blocks freed
    "ROUTE_MISS",     # api.Router.route memo-miss: (op, letter, trans, dims)
    "PROFILE_SWAP",   # tune.profile active-profile transition: tag
    "TUNE_CYCLE",     # tune.online cycle end: (cycle, retuned, timings,
                      #   swapped), dur_us = cycle wall time
))

#: One record: (t, type, rid, slot, arg, dur_us).  ``t`` is a
#: ``perf_counter`` second; ``rid``/``slot`` are -1 when not applicable;
#: ``arg`` is a small JSON-serializable payload; ``dur_us`` is set for
#: events that timed a section (prefill chunks).
Event = Tuple[float, str, int, int, Any, Optional[float]]

_CAP_ENV = "REPRO_TRACE_CAP"
_DEFAULT_CAP = 65536


class EventLog:
    """Fixed-capacity ring of :data:`Event` records.

    Appends are one ``deque.append`` on a ``maxlen`` deque — GIL-atomic,
    no lock on the emit path — and the deque drops the OLDEST event when
    full, so the ring always holds the most recent window.  ``dropped``
    is derived (``n_total - len(ring)``) rather than counted per drop,
    which keeps the emit path to two attribute ops.

    The ``on`` flag gates everything; it tracks the global ``REPRO_OBS``
    switch (see ``obs.set_enabled``) and additionally honours
    ``REPRO_TRACE=0`` so tracing can be disabled while metrics stay on
    (the overhead-gate comparisons in ``benchmarks/``).
    """

    def __init__(self, capacity: Optional[int] = None,
                 enabled: bool = True) -> None:
        if capacity is None:
            capacity = int(os.environ.get(_CAP_ENV, _DEFAULT_CAP))
        if capacity < 1:
            raise ValueError("EventLog capacity must be >= 1")
        self.capacity = capacity
        self.on = enabled
        self.n_total = 0
        self._ring: collections.deque = collections.deque(maxlen=capacity)

    # -- emit path (hot-ish; host scheduling code only) --------------------

    def emit(self, etype: str, rid: int = -1, slot: int = -1,
             arg: Any = None, dur_us: Optional[float] = None) -> None:
        if not self.on:
            return
        if etype not in EVENT_TYPES:
            raise ValueError(f"unknown trace event {etype!r}; "
                             f"expected one of {sorted(EVENT_TYPES)}")
        self.n_total += 1
        self._ring.append((time.perf_counter(), etype, rid, slot, arg,
                           dur_us))

    # -- views -------------------------------------------------------------

    @property
    def dropped(self) -> int:
        """Events lost to drop-oldest since the last reset."""
        return max(0, self.n_total - len(self._ring))

    def __len__(self) -> int:
        return len(self._ring)

    def snapshot(self) -> List[Event]:
        """Events oldest-first (a list copy; safe under concurrent
        emits — ``deque`` iteration over a snapshot list is not)."""
        return list(self._ring)

    def set_enabled(self, on: bool) -> None:
        self.on = bool(on)

    def reset(self) -> None:
        self._ring.clear()
        self.n_total = 0


def _trace_env_on() -> bool:
    v = os.environ.get("REPRO_TRACE")
    return (v or "1").strip().lower() not in ("0", "false", "off", "no")


#: The process-global flight recorder every emitter writes to.  Its
#: ``on`` flag is kept in lockstep with ``obs.set_enabled``; the module
#: is imported by ``repro.obs`` AFTER the kill switch is resolved.
TRACE = EventLog(enabled=_trace_env_on())


def emit(etype: str, rid: int = -1, slot: int = -1, arg: Any = None,
         dur_us: Optional[float] = None) -> None:
    """Module-level convenience over :data:`TRACE`."""
    TRACE.emit(etype, rid, slot, arg, dur_us)


# --------------------------------------------------------------------------
# Per-request reducer.
# --------------------------------------------------------------------------

def per_request(events: Iterable[Event]) -> Dict[int, dict]:
    """Derive per-request timing from the event stream.

    Returns ``rid -> record`` with (all times in microseconds):

    * ``queue_wait_us`` — submit → FIRST admission (the admission queue);
    * ``ttft_us`` / ``ttft_wait_us`` / ``ttft_prefill_us`` — time to
      first token split into time spent QUEUED (initial wait plus any
      pre-first-token preemption gaps) and time spent in a slot
      prefilling; ``ttft = wait + prefill`` by construction;
    * ``decode_stall_us`` — preempt→resume gaps AFTER the first token
      (decode progress frozen while re-queued);
    * ``preemptions``, ``prefill_chunks``, ``e2e_us``, ``n_out``,
      ``finished``.

    Requests whose REQ_ARRIVE fell off the ring still appear (anchored
    at their first surviving event) so a partial trace degrades to
    partial answers, never KeyErrors.
    """
    recs: Dict[int, dict] = {}
    waiting: Dict[int, float] = {}      # rid -> t it (re-)entered the queue

    def rec(rid: int, t: float) -> dict:
        r = recs.get(rid)
        if r is None:
            r = recs[rid] = {
                "rid": rid, "t_arrive": t, "t_first_admit": None,
                "t_first_token": None, "t_finish": None,
                "wait_us": 0.0, "decode_stall_us": 0.0,
                "preemptions": 0, "prefill_chunks": 0, "n_out": 0,
            }
        return r

    for t, etype, rid, slot, arg, dur in sorted(events, key=lambda e: e[0]):
        if rid < 0:
            continue                    # batch-wide / router events
        r = rec(rid, t)
        if etype == "REQ_ARRIVE":
            r["t_arrive"] = t
            waiting[rid] = t
        elif etype in ("ADMIT", "RESUME"):
            since = waiting.pop(rid, None)
            if since is not None:
                gap = (t - since) * 1e6
                if r["t_first_token"] is None:
                    r["wait_us"] += gap
                else:
                    r["decode_stall_us"] += gap
            if r["t_first_admit"] is None:
                r["t_first_admit"] = t
        elif etype == "PREEMPT":
            r["preemptions"] += 1
            waiting[rid] = t
        elif etype == "PREFILL_CHUNK":
            r["prefill_chunks"] += 1
        elif etype == "FIRST_TOKEN":
            if r["t_first_token"] is None:
                r["t_first_token"] = t
        elif etype == "FINISH":
            r["t_finish"] = t
            r["n_out"] = arg if isinstance(arg, int) else r["n_out"]

    out: Dict[int, dict] = {}
    for rid, r in recs.items():
        t_arr = r["t_arrive"]
        row = {
            "rid": rid,
            "preemptions": r["preemptions"],
            "prefill_chunks": r["prefill_chunks"],
            "decode_stall_us": round(r["decode_stall_us"], 1),
            "finished": r["t_finish"] is not None,
            "n_out": r["n_out"],
        }
        if r["t_first_admit"] is not None:
            row["queue_wait_us"] = round((r["t_first_admit"] - t_arr) * 1e6, 1)
        if r["t_first_token"] is not None:
            ttft = (r["t_first_token"] - t_arr) * 1e6
            wait = min(r["wait_us"], ttft)
            row["ttft_us"] = round(ttft, 1)
            row["ttft_wait_us"] = round(wait, 1)
            row["ttft_prefill_us"] = round(ttft - wait, 1)
        if r["t_finish"] is not None:
            row["e2e_us"] = round((r["t_finish"] - t_arr) * 1e6, 1)
        out[rid] = row
    return out


def observe(per_req: Dict[int, dict]) -> None:
    """Fold reducer output into the live metric registry (the BENCH
    export then carries the derived distributions next to the engine's
    own aggregates)."""
    from repro import obs
    for r in per_req.values():
        for field, metric in (("queue_wait_us", "serve.trace.queue_wait_us"),
                              ("ttft_wait_us", "serve.trace.ttft_wait_us"),
                              ("ttft_prefill_us",
                               "serve.trace.ttft_prefill_us"),
                              ("decode_stall_us",
                               "serve.trace.decode_stall_us")):
            if field in r:
                obs.histogram(metric).record(r[field])
        obs.histogram("serve.trace.preemptions").record(r["preemptions"])


def summary(per_req: Dict[int, dict]) -> dict:
    """Small comparable dict for BENCH ``meta`` blocks."""
    n = len(per_req)
    fin = [r for r in per_req.values() if r["finished"]]
    out = {"requests": n, "finished": len(fin),
           "preemptions": sum(r["preemptions"] for r in per_req.values())}

    def med(field):
        vs = sorted(r[field] for r in per_req.values() if field in r)
        return round(vs[len(vs) // 2], 1) if vs else None

    for field in ("queue_wait_us", "ttft_wait_us", "ttft_prefill_us",
                  "decode_stall_us"):
        v = med(field)
        if v is not None:
            out[f"{field[:-3]}_p50_us"] = v
    return out


# --------------------------------------------------------------------------
# Chrome-trace-event / Perfetto export.
# --------------------------------------------------------------------------

_PID_SERVE = 1
_PID_ROUTER = 2
_TID_QUEUE = 0                      # request queue track; slots are 1 + slot


def _meta(pid: int, tid: Optional[int], name: str, value: str,
          sort: Optional[int] = None) -> List[dict]:
    ev = {"ph": "M", "pid": pid, "name": name, "args": {"name": value}}
    if tid is not None:
        ev["tid"] = tid
    out = [ev]
    if sort is not None and tid is not None:
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_sort_index", "args": {"sort_index": sort}})
    return out


def perfetto(events: Iterable[Event], *,
             slots: Optional[int] = None) -> dict:
    """Render the event stream as a Chrome-trace-event document.

    Track layout: pid 1 ("repro.serve") has tid 0 = the admission queue
    and tid ``1+s`` = slot ``s``; pid 2 ("repro.router") carries
    ROUTE_MISS / PROFILE_SWAP instants on tid 0 and the online tuner's
    TUNE_CYCLE slices on its own tid 1 track (each cycle renders as a
    complete slice spanning its measured duration, so a miss burst on
    the route track lines up under the swap that caused it and the
    cycle that produced the swap).  Each request becomes a chain of
    complete ("X") slices — ``queued`` on the queue track, ``prefill`` /
    ``decode`` on the slot that ran it — linked by flow events
    (``s``/``t``/``f`` with ``id = rid``), so Perfetto draws the arrow
    from a preempted slice back through the queue to the resumed one:
    the preemption gap is the visible hole between them.
    """
    evs = sorted(events, key=lambda e: e[0])
    doc: dict = {"traceEvents": [], "displayTimeUnit": "ms"}
    te = doc["traceEvents"]
    if not evs:
        return doc
    t0 = evs[0][0]

    def us(t: float) -> float:
        return round((t - t0) * 1e6, 3)

    max_slot = max((e[3] for e in evs), default=-1)
    if slots is not None:
        max_slot = max(max_slot, slots - 1)
    te.extend(_meta(_PID_SERVE, None, "process_name", "repro.serve"))
    te.extend(_meta(_PID_SERVE, _TID_QUEUE, "thread_name", "queue", sort=0))
    for s in range(max_slot + 1):
        te.extend(_meta(_PID_SERVE, 1 + s, "thread_name", f"slot {s}",
                        sort=1 + s))
    te.extend(_meta(_PID_ROUTER, None, "process_name", "repro.router"))
    te.extend(_meta(_PID_ROUTER, 0, "thread_name", "route/profile", sort=0))
    te.extend(_meta(_PID_ROUTER, 1, "thread_name", "online tuner", sort=1))

    # per-request open slice: (t_start, tid, phase_name)
    open_slice: Dict[int, Tuple[float, int, str]] = {}
    flown: Dict[int, bool] = {}     # rid -> a flow chain has started
    t_end = evs[-1][0]

    def close(rid: int, t: float, flow_out: bool) -> None:
        """Emit the open slice of ``rid`` ending at ``t`` (+ flow)."""
        sl = open_slice.pop(rid, None)
        if sl is None:
            return
        ts, tid, phase = sl
        te.append({"ph": "X", "pid": _PID_SERVE, "tid": tid,
                   "name": f"req {rid} {phase}", "cat": "request",
                   "ts": us(ts), "dur": max(us(t) - us(ts), 0.001),
                   "args": {"rid": rid}})
        mid = us(ts) + (us(t) - us(ts)) / 2
        if not flown.get(rid):
            te.append({"ph": "s", "pid": _PID_SERVE, "tid": tid,
                       "cat": "request", "name": f"req {rid}",
                       "id": rid, "ts": mid})
            flown[rid] = True
        else:
            te.append({"ph": "t" if flow_out else "f", "bp": "e",
                       "pid": _PID_SERVE, "tid": tid, "cat": "request",
                       "name": f"req {rid}", "id": rid, "ts": mid})

    def open_(rid: int, t: float, tid: int, phase: str) -> None:
        open_slice[rid] = (t, tid, phase)

    for t, etype, rid, slot, arg, dur in evs:
        if etype == "REQ_ARRIVE":
            open_(rid, t, _TID_QUEUE, "queued")
        elif etype in ("ADMIT", "RESUME"):
            close(rid, t, flow_out=True)
            open_(rid, t, 1 + slot, "prefill")
        elif etype == "FIRST_TOKEN":
            close(rid, t, flow_out=True)
            open_(rid, t, 1 + slot, "decode")
        elif etype == "PREEMPT":
            close(rid, t, flow_out=True)
            open_(rid, t, _TID_QUEUE, "queued (preempted)")
            te.append({"ph": "i", "pid": _PID_SERVE, "tid": 1 + slot,
                       "name": f"preempt req {rid}", "cat": "sched",
                       "ts": us(t), "s": "t"})
        elif etype == "FINISH":
            close(rid, t, flow_out=False)
        elif etype == "PREFILL_CHUNK" and dur:
            te.append({"ph": "X", "pid": _PID_SERVE, "tid": 1 + slot,
                       "name": "prefill_chunk", "cat": "chunk",
                       "ts": max(us(t) - round(dur, 3), 0.0),
                       "dur": round(dur, 3),
                       "args": {"rid": rid, "span": arg}})
        elif etype == "DECODE_TICK":
            te.append({"ph": "i", "pid": _PID_SERVE, "tid": _TID_QUEUE,
                       "name": "decode_tick", "cat": "sched",
                       "ts": us(t), "s": "p",
                       "args": {"tick": arg}})
        elif etype == "EVICT":
            te.append({"ph": "i", "pid": _PID_SERVE, "tid": _TID_QUEUE,
                       "name": f"evict req {rid}", "cat": "sched",
                       "ts": us(t), "s": "t", "args": {"blocks": arg}})
        elif etype == "ROUTE_MISS":
            te.append({"ph": "i", "pid": _PID_ROUTER, "tid": 0,
                       "name": "route_miss", "cat": "router",
                       "ts": us(t), "s": "t", "args": {"sig": arg}})
        elif etype == "PROFILE_SWAP":
            te.append({"ph": "i", "pid": _PID_ROUTER, "tid": 0,
                       "name": "profile_swap", "cat": "router",
                       "ts": us(t), "s": "p", "args": {"profile": arg}})
        elif etype == "TUNE_CYCLE":
            # emitted at cycle END with the cycle wall time; render the
            # slice backwards from t so it covers the work it timed
            if dur:
                te.append({"ph": "X", "pid": _PID_ROUTER, "tid": 1,
                           "name": "tune_cycle", "cat": "tuner",
                           "ts": max(us(t) - round(dur, 3), 0.0),
                           "dur": round(dur, 3), "args": {"cycle": arg}})
            else:
                te.append({"ph": "i", "pid": _PID_ROUTER, "tid": 1,
                           "name": "tune_cycle", "cat": "tuner",
                           "ts": us(t), "s": "t", "args": {"cycle": arg}})

    # close anything still open at the end of the capture window
    for rid in list(open_slice):
        close(rid, t_end, flow_out=False)
    return doc


def _events_to_json(events: List[Event]) -> list:
    if not events:
        return []
    t0 = events[0][0]
    return [[round((t - t0) * 1e6, 3), etype, rid, slot, arg, dur]
            for t, etype, rid, slot, arg, dur in events]


def _events_from_json(rows: list) -> List[Event]:
    return [(float(r[0]) * 1e-6, r[1], int(r[2]), int(r[3]), r[4],
             None if r[5] is None else float(r[5])) for r in rows]


def write_trace(path: os.PathLike, events: Optional[List[Event]] = None,
                *, slots: Optional[int] = None,
                log: Optional[EventLog] = None) -> pathlib.Path:
    """Write a self-contained trace file: a valid Chrome-trace-event
    JSON (open it in Perfetto / ``chrome://tracing`` as-is) that also
    embeds the raw ring under ``reproTrace`` so the CLI can re-derive
    the per-request metrics or re-export later.  ``events=None`` dumps
    the live :data:`TRACE` ring."""
    log = log if log is not None else TRACE
    if events is None:
        events = log.snapshot()
    events = sorted(events, key=lambda e: e[0])
    doc = perfetto(events, slots=slots)
    doc["reproTrace"] = {
        "schema": TRACE_SCHEMA_VERSION,
        "capacity": log.capacity,
        "dropped": log.dropped,
        "events": _events_to_json(events),
    }
    doc["otherData"] = {"per_request": sorted(
        per_request(events).values(), key=lambda r: r["rid"])}
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(doc, sort_keys=True) + "\n")
    tmp.replace(p)
    return p


def load_events(path: os.PathLike) -> List[Event]:
    """Raw events back out of a :func:`write_trace` file."""
    doc = json.loads(pathlib.Path(path).read_text())
    raw = doc.get("reproTrace")
    if raw is None:
        raise ValueError(f"{path}: not a repro trace (no reproTrace key)")
    schema = int(raw.get("schema", -1))
    if schema != TRACE_SCHEMA_VERSION:
        raise ValueError(f"{path}: trace schema {schema} != supported "
                         f"{TRACE_SCHEMA_VERSION}")
    return _events_from_json(raw["events"])
