"""Activation-sharding context: ``constrain(x, *logical_axes)``.

GSPMD propagates parameter shardings into most of the graph, but
scan-carried zeros (online-softmax stats, SSD states) and gather outputs
have no sharding source, and XLA resolves them to REPLICATED — we measured
attention compute replicated 16x across the model axis before these
constraints existed (EXPERIMENTS.md §Perf, iteration 0).

Model code calls ``constrain(x, "batch", "heads", ...)`` with *logical*
activation axes; outside an ``activation_sharding`` context this is an
identity, so unit tests and single-device smoke runs are untouched.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ActCtx:
    mesh: Mesh
    axes: Dict[str, Axis]


_CTX: contextvars.ContextVar[Optional[ActCtx]] = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, axes: Dict[str, Axis]):
    tok = _CTX.set(ActCtx(mesh, axes))
    try:
        yield
    finally:
        _CTX.reset(tok)


def _axis_size(mesh: Mesh, ax: Axis) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape.get(ax, 1)
    n = 1
    for a in ax:
        n *= mesh.shape.get(a, 1)
    return n


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None or x is None:
        return x
    if x.ndim != len(logical):
        return x
    resolved = []
    for dim, a in zip(x.shape, logical):
        ax = ctx.axes.get(a) if isinstance(a, str) else a
        # divisibility guard: drop the axis rather than force an
        # inefficient (or invalid) uneven sharding
        if ax is not None and dim % _axis_size(ctx.mesh, ax) != 0:
            ax = None
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))


def moe_shard_count() -> int:
    """Number of independent MoE dispatch groups (= data-parallel degree)."""
    ctx = _CTX.get()
    if ctx is None:
        return 1
    return int(ctx.axes.get("_moe_shards", 1))


def activation_axes(cfg, mesh: Mesh, batch_axes: Axis) -> Dict[str, Axis]:
    """Logical activation axes -> mesh axes (divisibility-checked)."""
    md = mesh.shape.get("model", 1)

    def ok(n):
        return "model" if n and n % md == 0 else None

    axes: Dict[str, Axis] = {
        "batch": batch_axes,
        "heads": ok(cfg.n_heads_padded),
        "kv": ok(cfg.n_kv_heads_padded),
        "mlp": ok(cfg.d_ff),
        "vocab": ok(cfg.vocab_padded),
        "seq": None,
    }
    if cfg.moe:
        # per-shard MoE dispatch (§Perf iteration 2): one dispatch group
        # per batch shard; the group axis carries the batch sharding
        ba = batch_axes if batch_axes else None
        axes["moe_group"] = ba
        axes["_moe_shards"] = _axis_size(mesh, ba)
        if cfg.moe.num_experts % md == 0:
            axes["experts"] = "model"
            axes["expert_mlp"] = None
        else:
            axes["experts"] = None
            axes["expert_mlp"] = ok(cfg.moe.d_expert)
    if cfg.ssm:
        axes["inner"] = ok(cfg.d_inner)
        axes["ssm_heads"] = ok(cfg.ssm_heads)
    return axes
