"""Logical-axis -> mesh-axis sharding rules (DP / FSDP / TP / EP / SP).

Model code annotates parameters with *logical* axes ("embed", "heads",
"mlp", "vocab", "experts", ...).  This module maps them onto the physical
mesh with divisibility-aware fallbacks, implementing:

  TP    heads/kv_heads/mlp/expert_mlp/vocab/inner -> "model"
  EP    experts -> "model" when num_experts divides the axis (else the
        expert MLP dim takes the TP shard instead)
  FSDP  embed -> "data"  (ZeRO-3: params + optimizer state sharded over
        the data axis; XLA inserts the per-layer all-gathers inside the
        layer scan)
  DP    batch -> ("pod", "data") — the pod axis is *pure* DP so the only
        cross-pod traffic is one gradient reduce per step (DCN-friendly)
  SP    cache_seq -> "data" for the batch-1 long-context decode cells

Indivisible cases (smollm's 15 heads, gemma3's 4 heads on a 16-way model
axis) fall back to replication for that tensor — recorded by
``Rules.report()`` so the dry-run log shows every fallback explicitly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class Rules:
    mesh: Mesh
    table: Dict[str, Optional[Tuple[str, ...]]]
    fallbacks: Dict[str, str]

    def spec(self, logical: Optional[Tuple]) -> P:
        if logical is None:
            return P()
        return P(*(self.table.get(ax) if isinstance(ax, str) else ax
                   for ax in logical))

    def sharding(self, logical: Optional[Tuple]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))

    def tree_shardings(self, spec_tree):
        # tuples (incl. ()) are sharding specs; None marks an ABSENT param
        # (e.g. olmo's non-parametric norms) and must stay None so the
        # sharding tree matches the param tree structure exactly
        return jax.tree.map(self.sharding, spec_tree,
                            is_leaf=lambda s: isinstance(s, tuple))

    def report(self) -> str:
        lines = [f"{k} -> {v}" for k, v in sorted(self.table.items())]
        lines += [f"FALLBACK {k}: {v}" for k, v in sorted(self.fallbacks.items())]
        return "\n".join(lines)


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def make_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
               shard_experts: bool = True) -> Rules:
    md = _axis(mesh, "model")
    dd = _axis(mesh, "data")
    t: Dict[str, Optional[Tuple[str, ...]]] = {"layers": None}
    fb: Dict[str, str] = {}

    def give(name: str, size: int, axis: str, reason_ok=True):
        ax = _axis(mesh, axis)
        if size and size % ax == 0 and reason_ok:
            t[name] = axis
        else:
            t[name] = None
            fb[name] = f"size {size} % {axis}({ax}) != 0 -> replicate"

    # TP axes
    H, Hkv, hd = cfg.n_heads_padded, cfg.n_kv_heads_padded, \
        (cfg.head_dim_ if cfg.n_heads else 0)
    give("heads", H * hd if H else 0, "model", reason_ok=H % md == 0 if H else False)
    give("kv_heads", Hkv * hd if Hkv else 0, "model",
         reason_ok=Hkv % md == 0 if Hkv else False)
    give("mlp", cfg.d_ff, "model")
    give("vocab", cfg.vocab_padded, "model")
    if cfg.ssm:
        give("inner", cfg.d_inner, "model")
        t["ssm_heads"] = None
    if cfg.moe:
        E, fe = cfg.moe.num_experts, cfg.moe.d_expert
        if shard_experts and E % md == 0:
            t["experts"] = "model"          # EP
            t["expert_mlp"] = None
        else:
            t["experts"] = None
            give("expert_mlp", fe, "model")
            if E % md:
                fb["experts"] = f"{E} experts % model({md}) != 0 -> TP on expert_mlp"
    # FSDP
    if fsdp and cfg.d_model % dd == 0:
        t["embed"] = "data"
    else:
        t["embed"] = None
        if fsdp:
            fb["embed"] = f"d_model {cfg.d_model} % data({dd}) != 0"
    return Rules(mesh, t, fb)


# --------------------------------------------------------------------------
# Input / cache shardings per shape cell.
# --------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, global_batch: int) -> Optional[Tuple[str, ...]]:
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= _axis(mesh, a)
    if axes and global_batch % n == 0:
        return axes
    # try data only
    if global_batch % _axis(mesh, "data") == 0:
        return ("data",)
    return None


def data_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   rules: Rules) -> Dict[str, NamedSharding]:
    """NamedShardings for batch inputs (tokens/labels/embeds)."""
    b = batch_spec(mesh, shape.global_batch)
    tok = NamedSharding(mesh, P(b, None))
    emb = NamedSharding(mesh, P(b, None, None))
    return {"tokens": tok, "labels": tok, "prefix_embeds": emb,
            "src_embeds": emb}


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: Rules) -> Dict[str, P]:
    """PartitionSpecs for KV/SSM cache tensors (leading L or n_apps dim).

    batch >= data axis -> shard batch; batch == 1 (long-context) -> shard
    the cache *sequence* dim over data (SP for decode)."""
    b = batch_spec(mesh, shape.global_batch)
    kvh = rules.table.get("kv_heads")
    seq = None
    if b is None:
        seq = "data"                        # SP: context-parallel cache
    elif kvh is None:
        # kv heads replicated (indivisible): shard the cache sequence dim
        # over model instead — decode softmax pays a small AR, the cache
        # pays nothing (§Perf iteration 6: 35 GiB -> ~4 GiB on smollm)
        seq = "model"
    attn = P(None, b, kvh, seq, None)
    return {
        "attn_k": attn, "attn_v": attn,
        "shared_k": attn, "shared_v": attn,
        "conv": P(None, b, None, rules.table.get("inner")),
        "ssm": P(None, b, rules.table.get("ssm_heads"), None, None),
        "self_k": attn, "self_v": attn,
        "cross_k": attn, "cross_v": attn,
        "pos": P(),
    }
