"""repro.serve — serving engines over the IAAT-routed model stack.

:class:`PagedEngine` (the only production engine): paged KV cache +
per-slot recurrent state + slot-level continuous batching (mid-flight
admission, chunked prefill, device-side sampling,
preempt-on-exhaustion) for every decoder-only family.
:class:`ContinuousBatcher`: the wave-based reference, retired to
tests/benchmarks as the temperature-0 parity oracle.
"""
from repro.serve.engine import (ContinuousBatcher, PagedEngine, Request,
                                make_serve_fns, sample)
from repro.serve.paged import (BlockAllocator, BlockTable, CacheMap,
                               OutOfBlocks, SlotStateStore)
from repro.serve.sched import Seq, SlotScheduler

__all__ = [
    "ContinuousBatcher", "PagedEngine", "Request", "make_serve_fns",
    "sample", "BlockAllocator", "BlockTable", "CacheMap", "OutOfBlocks",
    "SlotStateStore", "Seq", "SlotScheduler",
]
