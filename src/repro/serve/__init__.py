"""repro.serve — serving engines over the IAAT-routed model stack.

:class:`PagedEngine` (default): paged KV cache + slot-level continuous
batching (mid-flight admission, chunked prefill, device-side sampling,
preempt-on-exhaustion).  :class:`ContinuousBatcher`: the wave-based
reference implementation and SSM/hybrid fallback.
"""
from repro.serve.engine import (ContinuousBatcher, PagedEngine, Request,
                                make_serve_fns, sample)
from repro.serve.paged import (BlockAllocator, BlockTable, CacheMap,
                               OutOfBlocks)
from repro.serve.sched import Seq, SlotScheduler

__all__ = [
    "ContinuousBatcher", "PagedEngine", "Request", "make_serve_fns",
    "sample", "BlockAllocator", "BlockTable", "CacheMap", "OutOfBlocks",
    "Seq", "SlotScheduler",
]
