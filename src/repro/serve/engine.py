"""Serving engine: jit'd prefill/decode steps + a continuous-batching
scheduler (slot-based, request queue, per-slot EOS/length tracking).

decode-time projections are (B x d) @ (d x N) GEMMs with tiny B — the
paper's small-GEMM regime.  The engine takes ONE :class:`repro.api.Policy`
at construction (installed for the whole serving session — not re-entered
per projection); ``Policy(backend="tuned")`` routes those decode GEMMs
and the MoE expert FFN by the measured DeviceProfile.

Every request is traced through :mod:`repro.obs`: admission wait, time
to first token, end-to-end latency (all measured from ``submit``),
decode throughput per wave, and wave occupancy — the numbers the
serving-scale ROADMAP items are judged by (``BENCH_serve.json`` via
``benchmarks/serve_stream.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, obs
from repro.api import Policy
from repro.models.registry import Model


def make_serve_fns(model: Model, be: Optional[Policy] = None):
    """Returns (prefill_fn, decode_fn), both jit'd; decode donates cache.
    ``be=None`` snapshots the ambient installed policy once, here — the
    model-entry install point."""
    pol = be if be is not None else api.current_policy()

    def prefill(params, batch):
        return model.prefill(params, batch, pol)

    def decode(params, tokens, cache):
        return model.decode(params, {"tokens": tokens}, cache, pol)

    return (jax.jit(prefill),
            jax.jit(decode, donate_argnums=(2,)))


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,)
    max_new: int = 32
    out: Optional[List[int]] = None
    t_submit: float = 0.0              # perf_counter stamp set by submit()


class ContinuousBatcher:
    """Slot-based continuous batching over a fixed decode batch.

    Simplification vs a production server: prompts in one admission wave
    share a prefill call (padded to the longest), and slots refill between
    decode steps — the scheduling contract (admit / decode / evict-on-EOS)
    is the real one."""

    def __init__(self, model: Model, params, be: Optional[Policy] = None,
                 *, slots: int = 4, max_len: int = 256, eos: int = 2,
                 temperature: float = 0.0, seed: int = 0):
        # the policy is resolved ONCE at engine construction (model
        # entry); every projection below reads this frozen object.
        be = be if be is not None else api.current_policy()
        self.model, self.params, self.be = model, params, be
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}
        self._decode = jax.jit(
            lambda p, t, c: model.decode(p, {"tokens": t}, c, be))

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        obs.counter("serve.requests").inc()
        self.queue.append(req)

    def step(self) -> bool:
        """Admit and run ONE wave from the queue; False when idle.  The
        streaming benchmark drives this directly so new arrivals can be
        submitted between waves (Poisson arrivals against a wave-based
        scheduler — the admission-wait histogram prices that gap)."""
        if not self.queue:
            return False
        wave = [self.queue.pop(0) for _ in range(
            min(self.slots, len(self.queue)))]
        self._run_wave(wave)
        return True

    def run(self) -> Dict[int, List[int]]:
        while self.step():
            pass
        return self.done

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        t_admit = time.perf_counter()
        adm = obs.histogram("serve.admission_wait_us")
        for r in wave:
            adm.record((t_admit - r.t_submit) * 1e6)
        obs.histogram("serve.wave_occupancy").record(B / self.slots)
        S = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(wave):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        max_new = max(r.max_new for r in wave)
        with obs.span("serve.prefill"):
            logits, cache = self.model.prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.be,
                cache_len=min(S + max_new, self.max_len))
            logits = jax.block_until_ready(logits)
        outs = [[] for _ in wave]
        alive = np.ones(B, bool)
        cur = np.asarray(sample(logits, self.key, self.temperature))
        t_first = time.perf_counter()
        ttft = obs.histogram("serve.ttft_us")
        for i in range(B):
            outs[i].append(int(cur[i]))
            ttft.record((t_first - wave[i].t_submit) * 1e6)
        steps = max(r.max_new for r in wave) - 1
        decoded = 0
        with obs.span("serve.decode"):
            for _ in range(max(steps, 0)):
                if not alive.any():
                    break
                self.key, k = jax.random.split(self.key)
                logits, cache = self._decode(
                    self.params, jnp.asarray(cur[:, None]), cache)
                cur = np.asarray(sample(logits, k, self.temperature))
                for i in range(B):
                    if alive[i]:
                        tok = int(cur[i])
                        outs[i].append(tok)
                        decoded += 1
                        if tok == self.eos or \
                                len(outs[i]) >= wave[i].max_new:
                            alive[i] = False
        t_done = time.perf_counter()
        if decoded and t_done > t_first:
            obs.histogram("serve.decode_tok_s").record(
                decoded / (t_done - t_first))
        e2e = obs.histogram("serve.e2e_us")
        toks_out = obs.counter("serve.tokens")
        for r, o in zip(wave, outs):
            self.done[r.rid] = o
            e2e.record((t_done - r.t_submit) * 1e6)
            toks_out.inc(len(o))
