"""Serving engines: the slot-level paged engine (default) and the
wave-based reference batcher.

decode-time projections are (B x d) @ (d x N) GEMMs with tiny B — the
paper's small-GEMM regime.  Both engines take ONE :class:`repro.api.Policy`
at construction (installed for the whole serving session — not re-entered
per projection); ``Policy(backend="tuned")`` routes those decode GEMMs
and the MoE expert FFN by the measured DeviceProfile.

:class:`PagedEngine` is the production loop for EVERY decoder-only
family: a block/paged KV cache plus per-slot recurrent state
(:mod:`repro.serve.paged`), slot-level admission/eviction/preemption
(:mod:`repro.serve.sched`), chunked prefill interleaved with decode,
sampling fused into the jit'd decode step, and asynchronous token
draining — so the decode batch B stays slot-stable (the Router sees a
stationary shape histogram) and no per-token host sync starves the
tuned kernels.

:class:`ContinuousBatcher` is the wave-based reference implementation:
a wave shares one padded prefill and slots only refill between waves.
It is NOT a production path any more — it survives as the parity
oracle (``slots=1`` is exact unbatched generation, what the paged
differential tests compare against) and for engine-vs-engine
benchmarking in ``benchmarks/serve_stream.py``.

Every request is traced through :mod:`repro.obs`: admission wait, time
to first token, end-to-end latency (all measured from ``submit``),
slot occupancy, queue depth, preemptions and block-pool usage — the
numbers the serving-scale ROADMAP items are judged by
(``BENCH_serve.json`` via ``benchmarks/serve_stream.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, obs
from repro.api import Policy
from repro.models.registry import Model
from repro.serve import sched
from repro.serve.paged import CacheMap, OutOfBlocks, SlotStateStore


def make_serve_fns(model: Model, be: Optional[Policy] = None):
    """Returns (prefill_fn, decode_fn), both jit'd; decode donates cache.
    ``be=None`` snapshots the ambient installed policy once, here — the
    model-entry install point."""
    pol = be if be is not None else api.current_policy()

    def prefill(params, batch):
        return model.prefill(params, batch, pol)

    def decode(params, tokens, cache):
        return model.decode(params, {"tokens": tokens}, cache, pol)

    return (jax.jit(prefill),
            jax.jit(decode, donate_argnums=(2,)))


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,)
    max_new: int = 32
    out: Optional[List[int]] = None
    t_submit: float = 0.0              # perf_counter stamp set by submit()


def _round_up(n: int, m: int) -> int:
    return -(n // -m) * m


# ==========================================================================
# The paged engine (default).
# ==========================================================================

class PagedEngine:
    """Slot-level continuous batching over a paged KV cache.

    Per :meth:`step` iteration: admit queued requests into free slots
    (mid-flight), run ONE jit'd decode step over every decoding slot
    (sampling on device, tokens drained asynchronously every
    ``drain_every`` steps), and run ONE prefill chunk for the oldest
    prefilling request — so a long prompt never stalls ongoing decode.
    Block exhaustion preempts the youngest sequence (blocks AND its
    slot-state row released, generated tokens kept, re-queued at the
    front; resume re-prefills prompt+generated, which rebuilds the
    recurrent carry from zero inside the jit'd prefill step).

    Every lifecycle transition is recorded in the :data:`repro.obs.TRACE`
    flight recorder (REQ_ARRIVE here, ADMIT/RESUME/PREEMPT in the
    scheduler, EVICT in the cache map) so a single request's path
    through the queue/slots is reconstructible after the fact; decode
    steps are sampled 1-in-``TICK_SAMPLE`` to keep a long decode from
    flushing the ring."""

    TICK_SAMPLE = 8

    def __init__(self, model: Model, params, be: Optional[Policy] = None,
                 *, slots: int = 4, max_len: int = 256, eos: int = 2,
                 temperature: float = 0.0, seed: int = 0,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 chunk: int = 32, drain_every: int = 4, tuner=None):
        if model.paged_decode is None:
            raise ValueError(
                f"{model.cfg.name}: family {model.cfg.family!r} has no "
                "paged serving path")
        be = be if be is not None else api.current_policy()
        self.model, self.params, self.be = model, params, be
        # optional repro.tune.online.OnlineTuner: run() starts it and
        # stops it on drain, so `--online-tune` serving re-tunes hot
        # classes in the background for exactly the engine's lifetime
        self.tuner = tuner
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.temperature, self.chunk = temperature, chunk
        self.drain_every = max(1, drain_every)
        self.key = jax.random.PRNGKey(seed)
        # table width covers max_len, rounded so prefill pad rows (the
        # chunk tail past the prompt) always have a backing block
        table_len = _round_up(_round_up(max_len, block_size), chunk)
        if num_blocks is None:
            num_blocks = 1 + slots * (table_len // block_size)
        self.cache = CacheMap(num_blocks, block_size, table_len)
        self.state = SlotStateStore(slots)
        self.scheduler = sched.SlotScheduler(self.cache, slots, self.state)
        self.done: Dict[int, List[int]] = {}
        self._decode_steps = 0
        dtype = model.cfg.compute_dtype
        self._ps = model.init_paged_state(num_blocks, block_size, slots,
                                          dtype)
        self._cur = jnp.zeros((slots,), jnp.int32)
        # (token_array, [(seq, slot)]) per issued decode step, drained
        # in order; holding the arrays (instead of np.asarray per step)
        # is what lets device steps pipeline
        self._pending: List[tuple] = []

        def _decode(p, cur, ps, bt, pos, active, k):
            logits, ps = model.paged_decode(
                p, {"tokens": cur[:, None]}, ps, bt, pos, active, be)
            k, sub = jax.random.split(k)
            nxt = sample(logits[:, -1], sub, temperature)
            return nxt.astype(jnp.int32), ps, k

        def _prefill(p, toks, ps, bt, pos0, slot, seg_len, n_prompt,
                     last_idx):
            logits, ps = model.paged_prefill(
                p, {"tokens": toks}, ps, bt, pos0, slot, seg_len,
                n_prompt, be)
            row = jax.lax.dynamic_index_in_dim(logits[0], last_idx,
                                               axis=0, keepdims=False)
            return row, ps

        self._decode_fn = jax.jit(_decode, donate_argnums=(2,))
        self._prefill_fn = jax.jit(_prefill, donate_argnums=(2,))

    # -- API (mirrors ContinuousBatcher) -----------------------------------

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(f"request {req.rid} exceeds max_len "
                             f"{self.max_len}")
        obs.counter("serve.requests").inc()
        obs.TRACE.emit("REQ_ARRIVE", rid=req.rid,
                       arg=(len(req.prompt), req.max_new))
        seq = sched.Seq(req=req)
        # worst-case footprint: the longest possible resume target
        # (prompt + max_new-1 generated) prefilled with a chunk-padded
        # tail — what the fit check must clear for livelock-free preempt
        worst = _round_up(
            max(1, len(req.prompt) + req.max_new - 1), self.chunk)
        self.scheduler.submit(seq, fit_tokens=worst)

    def step(self) -> bool:
        """One scheduler iteration; False when fully idle."""
        worked = False
        now = time.perf_counter()
        for seq in self.scheduler.admit():
            worked = True
            if not seq.admitted_once:
                seq.admitted_once = True
                obs.histogram("serve.admission_wait_us").record(
                    (now - seq.req.t_submit) * 1e6)
        dec = [q for q in self.scheduler.decoding() if q.budget_left > 0]
        for q in list(dec):
            if q.state == sched.DECODE:
                self._ensure(q, q.pos + 1)
        dec = [q for q in self.scheduler.decoding() if q.budget_left > 0]
        if dec:
            self._issue_decode(dec)
            worked = True
        pre = self.scheduler.next_prefill()
        if pre is not None:
            self._prefill_chunk(pre)
            worked = True
        if self._pending and (
                len(self._pending) >= self.drain_every
                or not any(q.budget_left > 0
                           for q in self.scheduler.decoding())):
            self._drain()
        if worked:
            obs.histogram("serve.slot_occupancy").record(
                self.scheduler.active() / self.slots)
            obs.histogram("serve.queue_depth").record(
                len(self.scheduler.queue))
            obs.gauge("serve.blocks_in_use").set(self.cache.blocks_in_use)
        return worked

    def run(self) -> Dict[int, List[int]]:
        if self.tuner is not None:
            self.tuner.start()      # no-op under REPRO_ONLINE_TUNE=0
        try:
            stall = 0
            while True:
                if self.step():
                    stall = 0
                    continue
                if self._pending:
                    self._drain()
                    continue
                if not self.scheduler.has_work():
                    break
                stall += 1
                if stall > 10000:   # fail loudly, never hang
                    raise RuntimeError("paged engine stalled: "
                                       f"{self.scheduler.active()} live, "
                                       f"{len(self.scheduler.queue)} queued")
        finally:
            # clean shutdown on drain (or on a raise): the tuner thread
            # joins before run() returns, so no background timing work
            # outlives the engine loop
            if self.tuner is not None:
                self.tuner.stop()
        return self.done

    # -- internals ---------------------------------------------------------

    def _ensure(self, seq: sched.Seq, n_tokens: int) -> bool:
        """Back ``seq`` with blocks for ``n_tokens`` positions,
        preempting (youngest first) on exhaustion.  False when ``seq``
        itself was the victim (it is re-queued; stop working on it)."""
        drained = False
        while True:
            try:
                self.cache.ensure(seq.rid, n_tokens)
                return True
            except OutOfBlocks:
                if not drained and self._pending:
                    self._drain()      # EOS finishes may free blocks
                    drained = True
                    if seq.state != sched.DECODE and \
                            seq.state != sched.PREFILL:
                        return False   # finished during the drain
                    continue
                self._drain()
                victim = self.scheduler.preempt_victim(seq)
                if victim is None:
                    raise RuntimeError("block pool exhausted with no "
                                       "active sequence to preempt")
                if victim is seq and self.scheduler.active() == 1:
                    raise RuntimeError(
                        "block pool exhausted by a single sequence that "
                        "passed the admission fit check — pool leak?")
                self.scheduler.preempt(victim)
                if victim is seq:
                    return False

    def _issue_decode(self, dec: List[sched.Seq]) -> None:
        bt = np.zeros((self.slots, self.cache.nmax), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        act = np.zeros((self.slots,), bool)
        for q in dec:
            bt[q.slot] = self.cache.row(q.rid)
            pos[q.slot] = q.pos
            act[q.slot] = True
        self._cur, self._ps, self.key = self._decode_fn(
            self.params, self._cur, self._ps,
            jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(act), self.key)
        self._pending.append((self._cur, [(q, q.slot) for q in dec]))
        self._decode_steps += 1
        if obs.TRACE.on and self._decode_steps % self.TICK_SAMPLE == 0:
            obs.TRACE.emit("DECODE_TICK",
                           arg=(self._decode_steps, len(dec)))
        for q in dec:
            q.pos += 1
            q.inflight += 1

    def _prefill_chunk(self, seq: sched.Seq) -> None:
        p0, C = seq.pos, self.chunk
        if not self._ensure(seq, p0 + C):
            return                      # preempted itself; re-queued
        target = seq.target
        segment = target[p0:p0 + C]
        toks = np.zeros((1, C), np.int32)
        toks[0, :len(segment)] = segment
        final = (p0 + len(segment)) == len(target)
        last_idx = np.int32(len(segment) - 1)
        t_chunk = time.perf_counter()
        row, self._ps = self._prefill_fn(
            self.params, jnp.asarray(toks), self._ps,
            jnp.asarray(self.cache.row(seq.rid)[None]),
            jnp.asarray([p0], dtype=jnp.int32), np.int32(seq.slot),
            np.int32(len(segment)), np.int32(len(seq.req.prompt)),
            last_idx)
        seq.pos = p0 + len(segment)
        obs.counter("serve.prefill_chunks").inc()
        obs.TRACE.emit(
            "PREFILL_CHUNK", rid=seq.rid, slot=seq.slot,
            arg=(p0, len(segment)),
            dur_us=(time.perf_counter() - t_chunk) * 1e6)
        if not final:
            return
        # host-side sample for the prefill boundary token only — every
        # subsequent token is sampled inside the jit'd decode step
        self.key, k = jax.random.split(self.key)
        tok = int(np.asarray(sample(row, k, self.temperature)))
        seq.out.append(tok)
        obs.counter("serve.tokens").inc()
        if len(seq.out) == 1:
            obs.histogram("serve.ttft_us").record(
                (time.perf_counter() - seq.req.t_submit) * 1e6)
            obs.TRACE.emit("FIRST_TOKEN", rid=seq.rid, slot=seq.slot)
        # like the wave reference, the request's FIRST token is exempt
        # from EOS (a request always yields at least one token); a
        # post-preemption boundary token is an ordinary decode token
        # and does get the EOS check
        if (tok == self.eos and len(seq.out) > 1) \
                or len(seq.out) >= seq.req.max_new:
            self._finish(seq)
        else:
            seq.state = sched.DECODE
            self._cur = self._cur.at[seq.slot].set(tok)

    def _drain(self) -> None:
        """Pull every pending decode token to the host in one pass and
        apply EOS / token-budget eviction with the (bounded) lag the
        async pipeline allows."""
        pend, self._pending = self._pending, []
        for arr, entries in pend:
            host = np.asarray(arr)
            for q, slot in entries:
                q.inflight -= 1
                if q.state != sched.DECODE:
                    continue            # evicted earlier in this drain
                tok = int(host[slot])
                q.out.append(tok)
                obs.counter("serve.tokens").inc()
                if tok == self.eos or len(q.out) >= q.req.max_new:
                    self._finish(q)

    def _finish(self, seq: sched.Seq) -> None:
        self.done[seq.rid] = seq.out
        obs.histogram("serve.e2e_us").record(
            (time.perf_counter() - seq.req.t_submit) * 1e6)
        obs.TRACE.emit("FINISH", rid=seq.rid, slot=seq.slot,
                       arg=len(seq.out))
        self.scheduler.finish(seq)


# ==========================================================================
# The wave-based reference engine.
# ==========================================================================

class ContinuousBatcher:
    """Wave-based continuous batching over a fixed decode batch.

    Simplification vs the paged engine: prompts in one admission wave
    share a prefill call (padded to the longest), ``cache_len`` is
    pre-committed for the whole wave, and slots only refill between
    waves.  Retired from production serving (the launcher only builds
    :class:`PagedEngine` now); kept as the parity ORACLE — ``slots=1``
    is exact unbatched generation, the baseline the paged differential
    suite compares every family against — and for the engine-vs-engine
    benchmark in ``benchmarks/serve_stream.py``."""

    def __init__(self, model: Model, params, be: Optional[Policy] = None,
                 *, slots: int = 4, max_len: int = 256, eos: int = 2,
                 temperature: float = 0.0, seed: int = 0):
        # the policy is resolved ONCE at engine construction (model
        # entry); every projection below reads this frozen object.
        be = be if be is not None else api.current_policy()
        self.model, self.params, self.be = model, params, be
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: Deque[Request] = collections.deque()
        self.done: Dict[int, List[int]] = {}

        def _decode(p, t, c, k):
            logits, c = model.decode(p, {"tokens": t}, c, be)
            # sampling fused into the step: only (B,) token ids cross
            # to the host, never the (B, V) logits
            return sample(logits, k, temperature).astype(jnp.int32), c

        self._decode = jax.jit(_decode)

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        obs.counter("serve.requests").inc()
        self.queue.append(req)

    def step(self) -> bool:
        """Admit and run ONE wave from the queue; False when idle.  The
        streaming benchmark drives this directly so new arrivals can be
        submitted between waves (Poisson arrivals against a wave-based
        scheduler — the admission-wait histogram prices that gap)."""
        if not self.queue:
            return False
        wave = [self.queue.popleft() for _ in range(
            min(self.slots, len(self.queue)))]
        self._run_wave(wave)
        return True

    def run(self) -> Dict[int, List[int]]:
        while self.step():
            pass
        return self.done

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        t_admit = time.perf_counter()
        adm = obs.histogram("serve.admission_wait_us")
        for r in wave:
            adm.record((t_admit - r.t_submit) * 1e6)
        obs.histogram("serve.wave_occupancy").record(B / self.slots)
        S = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(wave):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        max_new = max(r.max_new for r in wave)
        with obs.span("serve.prefill"):
            logits, cache = self.model.prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.be,
                cache_len=min(S + max_new, self.max_len))
            logits = jax.block_until_ready(logits)
        outs = [[] for _ in wave]
        alive = np.ones(B, bool)
        cur = np.asarray(sample(logits, self.key, self.temperature))
        t_first = time.perf_counter()
        ttft = obs.histogram("serve.ttft_us")
        for i in range(B):
            outs[i].append(int(cur[i]))
            ttft.record((t_first - wave[i].t_submit) * 1e6)
        steps = max(r.max_new for r in wave) - 1
        decoded = 0
        cur_dev = jnp.asarray(cur.astype(np.int32))
        with obs.span("serve.decode"):
            for _ in range(max(steps, 0)):
                if not alive.any():
                    break
                self.key, k = jax.random.split(self.key)
                cur_dev, cache = self._decode(
                    self.params, cur_dev[:, None], cache, k)
                cur = np.asarray(cur_dev)
                for i in range(B):
                    if alive[i]:
                        tok = int(cur[i])
                        outs[i].append(tok)
                        decoded += 1
                        if tok == self.eos or \
                                len(outs[i]) >= wave[i].max_new:
                            alive[i] = False
        t_done = time.perf_counter()
        if decoded and t_done > t_first:
            obs.histogram("serve.decode_tok_s").record(
                decoded / (t_done - t_first))
        e2e = obs.histogram("serve.e2e_us")
        toks_out = obs.counter("serve.tokens")
        for r, o in zip(wave, outs):
            self.done[r.rid] = o
            e2e.record((t_done - r.t_submit) * 1e6)
            toks_out.inc(len(o))
