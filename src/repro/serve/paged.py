"""Paged KV cache bookkeeping: a fixed pool of cache blocks, a free-list
allocator, and per-request block tables.

This module is pure host-side state — no jax arrays.  The device pools
(``(L, P, Hkv, BLOCK, hd)`` per layer, stacked) live in the engine and
are indexed *through* the tables built here: logical token position
``p`` of request ``r`` lives in pool block ``table[r][p // BLOCK]`` at
offset ``p % BLOCK``.  Because blocks are allocated on demand and freed
on EOS/eviction, ``cache_len`` is never pre-committed per wave (the
wave engine's core memory flaw) and a long-finished request's memory is
immediately reusable by the next admission.

Block 0 is reserved as the *null sink*: inactive decode slots carry an
all-zero table row, so their (masked, discarded) writes land in block 0
and can never alias a live request's cache.  The allocator therefore
hands out ids ``1 .. num_blocks-1`` only.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro import obs

__all__ = ["OutOfBlocks", "BlockAllocator", "BlockTable", "CacheMap",
           "SlotStateStore"]


class OutOfBlocks(RuntimeError):
    """Free list exhausted — the scheduler preempts and re-queues."""


class BlockAllocator:
    """Free-list allocator over a fixed pool of ``num_blocks`` blocks.

    Invariants (property-tested in tests/test_serve_paged.py):
      * no alias: a block id is held by at most one owner at a time;
      * no leak: free(everything allocated) restores full availability;
      * double-free and freeing the reserved null block raise.
    """

    NULL_BLOCK = 0

    def __init__(self, num_blocks: int) -> None:
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null sink)")
        self.num_blocks = num_blocks
        self._free: collections.deque = collections.deque(
            range(1, num_blocks))
        self._held: set = set()

    @property
    def capacity(self) -> int:
        """Allocatable blocks (the null sink is never handed out)."""
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._held)

    def alloc(self) -> int:
        if not self._free:
            raise OutOfBlocks(
                f"all {self.capacity} cache blocks in use")
        b = self._free.popleft()
        self._held.add(b)
        return b

    def free(self, ids: Iterable[int]) -> None:
        for b in ids:
            if b == self.NULL_BLOCK:
                raise ValueError("block 0 is the reserved null sink")
            if b not in self._held:
                raise ValueError(f"double free / foreign block {b}")
            self._held.remove(b)
            self._free.append(b)


class BlockTable:
    """Logical-order pool block ids for one request."""

    __slots__ = ("block_size", "ids")

    def __init__(self, block_size: int) -> None:
        self.block_size = block_size
        self.ids: List[int] = []

    @property
    def capacity(self) -> int:
        """Token positions currently backed by allocated blocks."""
        return len(self.ids) * self.block_size

    def ensure(self, n_tokens: int, allocator: BlockAllocator) -> int:
        """Grow the table to cover ``n_tokens`` positions; returns the
        number of blocks newly allocated.  Raises :class:`OutOfBlocks`
        mid-growth — already-allocated blocks stay in the table, so the
        caller can release the whole table on preemption."""
        grew = 0
        while self.capacity < n_tokens:
            self.ids.append(allocator.alloc())
            grew += 1
        return grew

    def row(self, nmax: int) -> np.ndarray:
        """Fixed-width int32 row (padded with the null block) — the unit
        the jit'd step consumes as one row of the (B, nmax) table."""
        if len(self.ids) > nmax:
            raise ValueError(f"request needs {len(self.ids)} blocks > "
                             f"table width {nmax}")
        out = np.zeros((nmax,), np.int32)
        out[:len(self.ids)] = self.ids
        return out


class SlotStateStore:
    """Host-side ledger for the per-slot recurrent-state rows.

    The device arrays themselves (conv carries + SSM state, one
    fixed-size row per slot) live inside the engine's
    :class:`repro.models.lm.PagedState`; this class owns WHICH request
    each row belongs to, in lockstep with block-table release: the
    scheduler calls :meth:`bind` on admission and :meth:`release` on
    finish / EOS-eviction / preemption, right next to
    ``CacheMap.release``.  The zero-reset of a re-bound row happens
    inside the jit'd prefill step (``pos_start == 0``), so a bind here
    never races device work and there is no host-side reset to forget.

    Invariants (tested in tests/test_serve_state.py):
      * a slot is owned by at most one request, a request owns at most
        one slot;
      * binding an occupied slot, re-binding a bound request, and
        releasing a request that holds no slot all raise;
      * a released slot is immediately rebindable.
    """

    def __init__(self, slots: int) -> None:
        if slots < 1:
            raise ValueError("need >= 1 slot")
        self.n_slots = slots
        self._owner: List[Optional[int]] = [None] * slots
        self._slot_of: Dict[int, int] = {}
        self.binds = 0
        self.releases = 0

    @property
    def bound(self) -> int:
        return len(self._slot_of)

    def owner(self, slot: int) -> Optional[int]:
        return self._owner[slot]

    def slot_of(self, rid: int) -> Optional[int]:
        return self._slot_of.get(rid)

    def bind(self, slot: int, rid: int) -> None:
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {self.n_slots})")
        if self._owner[slot] is not None:
            raise ValueError(f"slot {slot} already owned by request "
                             f"{self._owner[slot]}")
        if rid in self._slot_of:
            raise ValueError(f"request {rid} already bound to slot "
                             f"{self._slot_of[rid]}")
        self._owner[slot] = rid
        self._slot_of[rid] = slot
        self.binds += 1

    def release(self, rid: int) -> int:
        """Unbind ``rid``'s slot row; returns the freed slot."""
        slot = self._slot_of.pop(rid, None)
        if slot is None:
            raise ValueError(f"request {rid} holds no slot row")
        self._owner[slot] = None
        self.releases += 1
        return slot


class CacheMap:
    """Allocator + per-request block tables for one engine instance."""

    def __init__(self, num_blocks: int, block_size: int,
                 max_seq_len: int) -> None:
        self.allocator = BlockAllocator(num_blocks)
        self.block_size = block_size
        self.max_seq_len = max_seq_len
        # table width the jit'd step is specialised on
        self.nmax = -(max_seq_len // -block_size)
        self._tables: Dict[int, BlockTable] = {}

    def blocks_needed(self, n_tokens: int) -> int:
        return -(n_tokens // -self.block_size)

    def fits_ever(self, n_tokens: int) -> bool:
        """Whether a request of ``n_tokens`` total (prompt + max_new)
        could run even with the whole pool to itself."""
        return (self.blocks_needed(n_tokens) <= self.allocator.capacity
                and n_tokens <= self.nmax * self.block_size)

    def ensure(self, rid: int, n_tokens: int) -> None:
        """Back positions [0, n_tokens) of request ``rid`` with blocks.
        Raises :class:`OutOfBlocks` when the pool is exhausted."""
        t = self._tables.get(rid)
        if t is None:
            t = self._tables[rid] = BlockTable(self.block_size)
        t.ensure(n_tokens, self.allocator)

    def release(self, rid: int) -> int:
        """Free every block of ``rid`` (EOS / eviction / preemption);
        returns the number of blocks reclaimed."""
        t = self._tables.pop(rid, None)
        if t is None:
            return 0
        self.allocator.free(t.ids)
        obs.TRACE.emit("EVICT", rid=rid, arg=len(t.ids))
        return len(t.ids)

    def row(self, rid: int) -> np.ndarray:
        t = self._tables.get(rid)
        if t is None:
            return np.zeros((self.nmax,), np.int32)
        return t.row(self.nmax)

    @property
    def blocks_in_use(self) -> int:
        return self.allocator.in_use
