"""Slot-level continuous-batching scheduler.

State machine per request (DESIGN.md §Paged KV & slot scheduler)::

    QUEUED -> PREFILL -> DECODE -> DONE
       ^         |          |
       +---------+----------+   (preempt on block exhaustion: blocks
                                 released, generated tokens kept, the
                                 request re-queues at the FRONT and
                                 re-prefills prompt+generated on resume)

Unlike the wave engine (which admits a whole wave, then blocks until the
slowest member finishes), slots here are independent: a request is
admitted the moment a slot frees up — mid-decode of everyone else — and
evicted the moment it hits EOS or its token budget, returning its slot
AND its cache blocks to the pool immediately.

The scheduler is pure host-side state (queue, slots, per-seq counters)
so it unit-tests without a model; the engine owns the device work and
drives it via ``admit`` / ``next_prefill`` / ``decoding`` / ``finish``
/ ``preempt``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

from repro import obs
from repro.serve.paged import CacheMap, SlotStateStore

__all__ = ["QUEUED", "PREFILL", "DECODE", "DONE", "Seq", "SlotScheduler"]

QUEUED = "queued"
PREFILL = "prefilling"
DECODE = "decoding"
DONE = "done"


@dataclasses.dataclass
class Seq:
    """Scheduler-side view of one request.

    ``pos`` counts context tokens whose K/V sit in the pool; ``out`` is
    the drained generated tokens; ``inflight`` counts decode steps
    issued to the device but not yet drained back.  On preemption the
    generated prefix is kept: the resume target is ``prompt + out`` and
    prefill recomputes that whole context (recompute-style preemption —
    at temperature 0 the continuation is exactly what it would have
    been)."""
    req: object                         # engine.Request (duck-typed)
    state: str = QUEUED
    slot: int = -1
    pos: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    inflight: int = 0
    admit_seq: int = -1                 # admission stamp; victim = max
    preemptions: int = 0
    admitted_once: bool = False

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def target(self) -> List[int]:
        """Tokens prefill must put in the pool before decode resumes."""
        return list(self.req.prompt) + self.out

    @property
    def budget_left(self) -> int:
        """Decode steps still worth issuing (max_new minus drained and
        in-flight tokens)."""
        return self.req.max_new - len(self.out) - self.inflight


class SlotScheduler:
    """FIFO admission into free slots; per-slot eviction/preemption."""

    def __init__(self, cache: CacheMap, slots: int,
                 state: Optional[SlotStateStore] = None) -> None:
        self.cache = cache
        self.state = state          # slot-row ownership, lockstep below
        self.n_slots = slots
        self.queue: Deque[Seq] = collections.deque()
        self.slots: List[Optional[Seq]] = [None] * slots
        self.live: Dict[int, Seq] = {}          # rid -> Seq (active only)
        self._stamp = 0

    # -- admission ---------------------------------------------------------

    def submit(self, seq: Seq, fit_tokens: Optional[int] = None) -> None:
        """``fit_tokens`` is the engine's worst-case pool footprint for
        the request (chunk-rounded prefill tail included); a request
        that could not complete even with the whole pool to itself is
        rejected here, which is what makes preemption livelock-free."""
        total = fit_tokens or (len(seq.req.prompt) + seq.req.max_new)
        if not self.cache.fits_ever(total):
            raise ValueError(
                f"request {seq.rid}: {total} tokens can never fit the "
                f"pool ({self.cache.allocator.capacity} blocks x "
                f"{self.cache.block_size})")
        self.queue.append(seq)

    def admit(self) -> List[Seq]:
        """Fill free slots from the queue (FIFO); called every engine
        iteration, so admission happens mid-flight, not between waves."""
        admitted = []
        for s in range(self.n_slots):
            if self.slots[s] is not None or not self.queue:
                continue
            seq = self.queue.popleft()
            seq.slot, seq.state = s, PREFILL
            seq.pos = 0
            seq.admit_seq = self._stamp
            self._stamp += 1
            self.slots[s] = seq
            self.live[seq.rid] = seq
            if self.state is not None:
                self.state.bind(s, seq.rid)
            obs.TRACE.emit("RESUME" if seq.preemptions else "ADMIT",
                           rid=seq.rid, slot=s)
            admitted.append(seq)
        return admitted

    # -- queries -----------------------------------------------------------

    def next_prefill(self) -> Optional[Seq]:
        """Earliest-admitted sequence still prefilling (round-robin is
        unnecessary: chunks are short and admission order is fairness)."""
        cands = [q for q in self.live.values() if q.state == PREFILL]
        return min(cands, key=lambda q: q.admit_seq) if cands else None

    def decoding(self) -> List[Seq]:
        return [q for q in self.live.values() if q.state == DECODE]

    def active(self) -> int:
        return len(self.live)

    def has_work(self) -> bool:
        return bool(self.queue or self.live)

    # -- transitions -------------------------------------------------------

    def finish(self, seq: Seq) -> None:
        """EOS or token budget reached: slot, blocks AND the slot's
        recurrent-state row free NOW."""
        self.cache.release(seq.rid)
        if self.state is not None:
            self.state.release(seq.rid)
        if seq.slot >= 0:
            self.slots[seq.slot] = None
        self.live.pop(seq.rid, None)
        seq.state, seq.slot = DONE, -1

    def preempt_victim(self, needer: Seq) -> Optional[Seq]:
        """Youngest-admitted active sequence (possibly ``needer``
        itself) — oldest requests keep their blocks, preserving FIFO
        fairness."""
        if not self.live:
            return None
        return max(self.live.values(), key=lambda q: q.admit_seq)

    def preempt(self, seq: Seq) -> None:
        """Release everything and put the sequence back at the FRONT of
        the queue; generated tokens survive in ``seq.out``."""
        assert seq.inflight == 0, "drain before preempting"
        obs.TRACE.emit("PREEMPT", rid=seq.rid, slot=seq.slot)
        self.cache.release(seq.rid)
        if self.state is not None:
            self.state.release(seq.rid)
        if seq.slot >= 0:
            self.slots[seq.slot] = None
        self.live.pop(seq.rid, None)
        seq.state, seq.slot, seq.pos = QUEUED, -1, 0
        seq.preemptions += 1
        obs.counter("serve.preemptions").inc()
        self.queue.appendleft(seq)
