"""Fault-tolerant checkpointing: atomic, async, reshardable.

Layout (mesh-independent => elastic restarts can change the mesh):

    <dir>/step_<N>/
        manifest.json        # step, leaf paths, shapes, dtypes, extra state
        <leaf-path>.npy      # one file per pytree leaf (full array)

* Writes go to ``step_<N>.tmp`` then ``os.replace`` — a crash mid-save can
  never corrupt the latest checkpoint (restore scans for complete dirs).
* ``save`` can run on a background thread (async): training continues while
  the previous step's state (already device_get'd) is written.
* ``restore`` device_puts each leaf with the CURRENT mesh's sharding —
  resharding across mesh sizes is free because files hold full arrays.
  (Multi-host note: per-host shard files + a gather manifest would replace
  the full-array files; the manifest format already carries what's needed.)
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _safe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_./-]", "_", name).replace("/", "__")


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict] = None,
             async_: bool = False) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        # Always drain the previous async writer first: a sync save racing
        # an in-flight async save of the same step collides on the .tmp dir.
        self.wait()
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten(host_state)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for name, arr in leaves:
            fn = _safe(name) + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": hashlib.sha1(arr.tobytes()[:1 << 20]).hexdigest()[:12],
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """``like``: pytree matching the saved structure (values ignored).
        ``shardings``: optional matching pytree of NamedShardings — each
        leaf is device_put with its sharding (elastic resharding)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        files = {m["name"]: m["file"] for m in manifest["leaves"]}
        names = [n for n, _ in _flatten(like)]
        missing = [n for n in names if n not in files]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}")
        arrays = [np.load(os.path.join(d, files[n])) for n in names]
        treedef = jax.tree.structure(like)
        state = jax.tree.unflatten(treedef, arrays)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return state, manifest["extra"]
