"""Deterministic, restart-safe data pipelines.

* ``SyntheticTokens`` — counter-based RNG (Philox): batch(step) is a pure
  function of (seed, step, host), so a restarted/elastic job replays the
  exact token stream from its checkpointed cursor with zero saved state.
* ``MemmapTokens`` — memory-mapped binary token corpus with a step cursor.
* Both shard rows across hosts by process index (each host feeds its own
  slice of the global batch; ``make_global_batch`` assembles the global
  array on the current mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    with_labels: bool = True

    def batch(self, step: int, host: int = 0, num_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        rows = self.global_batch // num_hosts
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=(step * 1_000_003 + host)))
        toks = rng.integers(0, self.vocab, (rows, self.seq_len + 1),
                            dtype=np.int32)
        out = {"tokens": toks[:, :-1]}
        if self.with_labels:
            out["labels"] = toks[:, 1:]
        return out


@dataclasses.dataclass
class MemmapTokens:
    path: str
    seq_len: int
    global_batch: int
    dtype: str = "int32"
    _mm: Optional[np.memmap] = None

    def __post_init__(self):
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r")

    def batch(self, step: int, host: int = 0, num_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        rows = self.global_batch // num_hosts
        span = self.seq_len + 1
        n_tokens = self._mm.shape[0]
        per_step = self.global_batch * span
        base = (step * per_step + host * rows * span) % max(
            n_tokens - per_step, 1)
        flat = np.asarray(self._mm[base:base + rows * span]).astype(np.int32)
        toks = flat.reshape(rows, span)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_global_batch(host_batch: Dict[str, np.ndarray], shardings):
    """Assemble host-local rows into global device arrays.

    Single-process: a device_put with the target sharding.  Multi-host:
    jax.make_array_from_process_local_data handles the same contract."""
    out = {}
    for k, v in host_batch.items():
        s = shardings.get(k)
        if jax.process_count() > 1:
            out[k] = jax.make_array_from_process_local_data(s, v)
        else:
            out[k] = jax.device_put(v, s) if s is not None else v
    return out
