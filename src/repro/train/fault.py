"""Fault tolerance: step monitoring, straggler detection, restart policy.

At 1000+ nodes the assumptions are (a) *something* is always failing,
(b) checkpoint/restore is the only durable state, (c) stragglers cost more
than failures.  This module provides the local building blocks:

* ``StepMonitor``  — per-step wall-time EMA + z-score straggler flagging
  (on real pods, each host reports; the launcher aggregates and evicts).
* ``run_with_restarts`` — supervises a train function; on failure restores
  from the latest complete checkpoint and replays (data pipeline is
  counter-based, so replay is exact).
* ``SimulatedFault`` — deterministic fault injection for tests.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    straggler: bool


class StepMonitor:
    def __init__(self, z_thresh: float = 3.0, warmup: int = 5):
        self.z = z_thresh
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.history: List[StepStats] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> StepStats:
        dt = time.monotonic() - self._t0
        straggler = False
        if self.n >= self.warmup:
            sd = max(self.var ** 0.5, 1e-6)
            straggler = (dt - self.mean) / sd > self.z
        # EMA update (skip straggler samples so they don't mask themselves)
        if not straggler:
            self.n += 1
            a = 2.0 / (self.n + 1) if self.n < 50 else 0.04
            d = dt - self.mean
            self.mean += a * d
            self.var = (1 - a) * (self.var + a * d * d)
        st = StepStats(step, dt, straggler)
        self.history.append(st)
        if straggler:
            log.warning("straggler: step %d took %.3fs (mean %.3fs)",
                        step, dt, self.mean)
        return st

    def summary(self) -> Dict:
        if not self.history:
            return {}
        ts = [s.seconds for s in self.history]
        return {"steps": len(ts), "mean_s": sum(ts) / len(ts),
                "max_s": max(ts),
                "stragglers": sum(s.straggler for s in self.history)}


class SimulatedFault(Exception):
    pass


def run_with_restarts(train_once: Callable[[int], int], *,
                      max_restarts: int = 3) -> int:
    """``train_once(attempt) -> final_step``; restores internally from the
    checkpointer it owns.  Returns the final step reached."""
    attempt = 0
    while True:
        try:
            return train_once(attempt)
        except SimulatedFault as e:          # injected faults: always retry
            attempt += 1
            log.warning("fault (%s); restart %d/%d", e, attempt, max_restarts)
            if attempt > max_restarts:
                raise
        except (RuntimeError, OSError) as e:  # real runtime faults
            attempt += 1
            log.warning("fault (%s); restart %d/%d", e, attempt, max_restarts)
            if attempt > max_restarts:
                raise
