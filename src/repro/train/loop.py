"""Train-step builder: mixed precision, microbatched gradient accumulation,
family-aware loss, optimizer fusion — the function the dry-run lowers and
the trainer runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.api import Policy
from repro.models.registry import Model
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt.OptConfig = opt.OptConfig()
    accum_steps: int = 1               # microbatch gradient accumulation
    z_loss: float = 1e-4


def init_train_state(model: Model, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": opt.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(model: Model) -> Dict[str, Any]:
    ps = model.specs()
    return {"params": ps, "opt": {"m": ps, "v": ps}, "step": ()}


def _xent(logits, labels, vocab: int, z_loss: float):
    """Masked cross-entropy in f32 + z-loss; labels == -1 are ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.clip(labels, 0, vocab - 1)[..., None],
                             axis=-1)[..., 0]
    valid = (labels >= 0) & (labels < vocab)
    per_tok = (lse - ll) + z_loss * lse ** 2
    per_tok = jnp.where(valid, per_tok, 0.0)
    n = jnp.maximum(valid.sum(), 1)
    return per_tok.sum() / n, n


def record_step(step: int, metrics: Dict[str, float],
                dt_s: float) -> None:
    """Fold one *executed* train step into the obs registry (called by
    the launcher after the host has blocked on the step's metrics — a
    jit'd step cannot time itself).  ``BENCH`` exports and
    ``python -m repro.obs report`` read these."""
    obs.counter("train.steps").inc()
    obs.histogram("train.step_us").record(dt_s * 1e6)
    obs.gauge("train.step").set(step)
    if "loss" in metrics:
        obs.gauge("train.loss").set(float(metrics["loss"]))


def make_loss_fn(model: Model, tc: TrainConfig, be: Policy) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward_train(params, batch, be)
        tokens = batch["tokens"]
        if cfg.frontend == "vision":
            logits = logits[:, cfg.frontend_tokens:]
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.concatenate(
                [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)
        ce, n = _xent(logits, labels, cfg.vocab, tc.z_loss)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "tokens": n}

    return loss_fn


def _split_micro(batch: Dict[str, jax.Array], accum: int):
    def sp(x):
        return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
    return jax.tree.map(sp, batch)


def cast_params_for_compute(params, dtype):
    """f32 master -> bf16 working copy, ONCE per step (outside the accum
    scan) so ZeRO-3 all-gathers inside the scan move bf16, not f32 —
    measured 2x collective-bytes reduction on the mixtral train cell.

    Precision-sensitive leaves stay f32: 1-D params (norms, A_log,
    dt_bias, D) and MoE router weights."""
    def cast(path, p):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        if p.ndim < 2 or "router" in name:
            return p
        return p.astype(dtype)
    return jax.tree_util.tree_map_with_path(cast, params)


def make_train_step(model: Model, tc: TrainConfig, be: Policy) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    With ``accum_steps > 1`` the global batch is split along the batch dim
    and gradients are accumulated in f32 via lax.scan (activation memory
    scales 1/accum — how the 141B mixtral train cell fits v5e HBM)."""
    loss_fn = make_loss_fn(model, tc, be)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        pc = cast_params_for_compute(params, model.cfg.compute_dtype)
        if tc.accum_steps > 1:
            micro = _split_micro(batch, tc.accum_steps)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, _), g = vg(pc, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            (gsum, lsum), _ = lax.scan(body, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / tc.accum_steps, gsum)
            loss = lsum / tc.accum_steps
            metrics = {}
        else:
            (loss, metrics), grads = vg(pc, batch)
        new_params, new_opt, om = opt.adamw_update(
            params, grads, state["opt"], state["step"], tc.opt)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out = {"loss": loss, **om}
        return new_state, out

    return train_step
