"""In-house AdamW + warmup-cosine schedule + global-norm clipping.

Optimizer state shards exactly like the parameters (FSDP/ZeRO: the rules'
param shardings are reused for m/v/master), so memory per chip is
(4+4+4)·N/|mesh| bytes for f32 master+m+v.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step, c: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = c.peak_lr * (step + 1) / max(c.warmup_steps, 1)
    t = jnp.clip((step - c.warmup_steps)
                 / max(c.decay_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.minimum(warm, c.peak_lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, step, c: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, c)
    b1c = 1 - c.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1 - c.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + c.eps)
        if p.ndim >= 2:                      # no decay on norms/biases/scalars
            step_ = step_ + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
