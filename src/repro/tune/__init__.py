"""repro.tune — the empirical install-time stage.

The analytical pipeline (cost.py prior, TPU_SCALE crossover, _choose_bk)
predicts; this package *measures*.  It buckets the continuous
(M, N, K, dtype, trans) input space into geometric size classes
(classes.py), micro-benchmarks the analytically-promising kernel
candidates plus the XLA baseline per class (timer.py + search.py), and
persists the winners as a versioned per-device :class:`DeviceProfile`
(profile.py) that the ``repro.api`` Router consults at call time under
``Policy(backend="tuned")`` — for the 2-D entry, ND matmul, and the
grouped MoE/serving paths alike — falling back to the analytical model
for unmeasured classes.

``python -m repro.tune`` runs the sweep and writes the profile; the
*online* stage (online.py) re-runs a budgeted slice of it continuously,
weighted by the live ``ROUTES.windowed()`` traffic, and swaps the
merged profile in without restarting the engine.
"""
from repro.tune.classes import SizeClass, size_class, representative
from repro.tune.online import CycleReport, OnlineTuner, weighted_targets
from repro.tune.profile import (DeviceProfile, ProfileEntry, active_profile,
                                clear_active_profile, default_profile_path,
                                set_active_profile)
from repro.tune.search import (TuneTarget, budgeted_sweep, sweep, tune_class,
                               tune_grouped_class)
from repro.tune.timer import Measurement, measure

__all__ = [
    "SizeClass", "size_class", "representative",
    "DeviceProfile", "ProfileEntry", "active_profile",
    "clear_active_profile", "default_profile_path", "set_active_profile",
    "sweep", "tune_class", "tune_grouped_class", "budgeted_sweep",
    "TuneTarget", "OnlineTuner", "CycleReport", "weighted_targets",
    "Measurement", "measure",
]
