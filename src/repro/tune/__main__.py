"""``python -m repro.tune`` — run the empirical install-time sweep.

Examples::

    python -m repro.tune --letters S --trans NN --quick
    python -m repro.tune --letters SD --trans NN,NT --max-dim 1024 --compiled
    python -m repro.tune --show        # print the active profile, no sweep

Writes the versioned DeviceProfile JSON to the per-device default path
(override with --out / $REPRO_TUNE_CACHE) and merges with any existing
profile unless --no-merge.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.tune import classes as classes_mod
from repro.tune import profile as profile_mod
from repro.tune import search


def _parse_letters(s: str):
    letters = [c for c in s.upper().replace(",", "") if not c.isspace()]
    for c in letters:
        if c not in ("S", "D", "C", "Z", "H"):
            raise argparse.ArgumentTypeError(f"unknown BLAS letter {c!r}")
    return letters


def _parse_trans(s: str):
    out = [t.strip().upper() for t in s.split(",") if t.strip()]
    for t in out:
        if t not in ("NN", "NT", "TN", "TT"):
            raise argparse.ArgumentTypeError(f"unknown transposition {t!r}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Empirical IAAT tuning sweep -> persistent DeviceProfile")
    ap.add_argument("--letters", type=_parse_letters, default=["S"],
                    help="BLAS dtype letters, e.g. S, SD, S,D (default S)")
    ap.add_argument("--trans", type=_parse_trans, default=["NN"],
                    help="comma-separated transpositions (default NN)")
    ap.add_argument("--min-dim", type=int, default=8)
    ap.add_argument("--max-dim", type=int, default=512)
    ap.add_argument("--top", type=int, default=4,
                    help="candidates timed per class after the prior prune")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--quick", action="store_true",
                    help="cube classes only, max-dim 128, reps 3, top 2 "
                         "(CI / interpret-mode smoke)")
    ap.add_argument("--compiled", action="store_true",
                    help="time compiled kernels (real TPU) instead of "
                         "interpret mode")
    ap.add_argument("--out", default=None,
                    help="profile path (default: per-device cache path)")
    ap.add_argument("--no-merge", action="store_true",
                    help="overwrite instead of merging an existing profile")
    ap.add_argument("--show", action="store_true",
                    help="print the profile at the target path and exit")
    args = ap.parse_args(argv)

    mode = "compiled" if args.compiled else "interpret"
    path = args.out or profile_mod.default_profile_path(mode=mode)
    if args.show:
        # without --out, show what tuned dispatch would actually load
        # (compiled preferred over interpret)
        show_path = args.out or profile_mod.find_default_profile() or path
        try:
            prof = profile_mod.DeviceProfile.load(show_path)
        except (OSError, ValueError, KeyError) as e:
            print(f"no profile at {show_path}: {e}", file=sys.stderr)
            return 1
        print(json.dumps(prof.to_json(), indent=1, sort_keys=True))
        return 0

    if args.quick:
        args.max_dim = min(args.max_dim, 128)
        args.reps = min(args.reps, 3)
        args.top = min(args.top, 2)

    def progress(sc, entry):
        winner = "pallas" if entry.prefer_pallas else "xla"
        sig = entry.sig.name if entry.sig else "-"
        pal = f"{entry.pallas.median_us:9.1f}" if entry.pallas else "     fail"
        xla = f"{entry.xla.median_us:9.1f}" if entry.xla else "     fail"
        print(f"  {sc.key:<18} pallas {pal}us  xla {xla}us  "
              f"-> {winner:<6} {sig}")

    n_classes = len(classes_mod.classes_up_to(
        args.letters, args.trans, args.max_dim, min_dim=args.min_dim,
        cube_only=args.quick))
    mode = "interpret" if not args.compiled else "compiled"
    print(f"tuning {n_classes} size classes "
          f"({''.join(args.letters)} x {','.join(args.trans)}, "
          f"dims {args.min_dim}..{args.max_dim}, {mode} mode)")
    prof = search.sweep(args.letters, args.trans,
                        min_dim=args.min_dim, max_dim=args.max_dim,
                        cube_only=args.quick, top=args.top,
                        warmup=args.warmup, reps=args.reps,
                        interpret=not args.compiled, progress=progress)
    if not args.no_merge:
        try:
            prof = profile_mod.DeviceProfile.load(path).merge(prof)
        except (OSError, ValueError, KeyError):
            pass        # absent or unusable existing profile: overwrite
    written = prof.save(path)
    profile_mod.clear_active_profile()   # next tuned dispatch sees the update
    n_pallas = sum(e.prefer_pallas for e in prof.entries.values())
    print(f"wrote {written} ({len(prof)} classes, "
          f"{n_pallas} prefer pallas)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
