"""Geometric size-class bucketing over (M, N, K, dtype, trans).

A profile cannot store one entry per exact problem shape — the input
space is continuous.  Instead each dimension is bucketed geometrically
(ratio ``GROWTH``), so a bounded number of classes covers every size up
to the small-GEMM crossover and beyond, and shapes within ~GROWTH of
each other — whose kernel choice is the same in practice — share one
measured entry.  Tillet's input-aware tuner makes the same move with a
learned classifier; fixed geometric buckets keep lookup a pure integer
computation with zero model state.

Bucket i covers [GROWTH**i, GROWTH**(i+1)) and its *representative* (the
shape actually benchmarked for the class) is the geometric midpoint
round(GROWTH**(i+0.5)), which minimises worst-case ratio error across
the bucket.  Bucketing is deterministic and endpoint-stable: bucket
boundaries are precomputed integers, so float noise in ``log`` cannot
flip a boundary size between classes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

GROWTH = 2.0
_MAX_BUCKET = 64          # covers dims up to 2**64 — effectively unbounded


def _bucket_edges(max_bucket: int = _MAX_BUCKET) -> Tuple[int, ...]:
    # edges[i] = smallest integer size that falls in bucket i
    return tuple(int(math.ceil(GROWTH ** i)) for i in range(max_bucket + 1))


_EDGES = _bucket_edges()


def bucket_index(x: int) -> int:
    """Index i of the geometric bucket containing integer size ``x >= 1``."""
    if x < 1:
        raise ValueError(f"size must be >= 1, got {x}")
    # binary search over the precomputed integer edges — deterministic at
    # boundaries, unlike floor(log(x)/log(GROWTH)) which can ride float
    # error for exact powers.
    lo, hi = 0, len(_EDGES) - 1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if _EDGES[mid] <= x:
            lo = mid
        else:
            hi = mid - 1
    return lo


def bucket_bounds(i: int) -> Tuple[int, int]:
    """[lo, hi) integer size range of bucket ``i``."""
    return _EDGES[i], _EDGES[i + 1]


def bucket_representative(i: int) -> int:
    """Benchmarked size for bucket ``i``: the geometric midpoint."""
    return max(1, int(round(GROWTH ** (i + 0.5))))


@dataclasses.dataclass(frozen=True, order=True)
class SizeClass:
    """One profile key: dtype letter, transposition, per-dim bucket ids."""
    letter: str
    trans: str
    mb: int
    nb: int
    kb: int

    @property
    def key(self) -> str:
        """Stable string key used in the JSON profile."""
        return f"{self.letter}/{self.trans}/{self.mb}-{self.nb}-{self.kb}"

    @classmethod
    def from_key(cls, key: str) -> "SizeClass":
        letter, trans, buckets = key.split("/")
        mb, nb, kb = (int(b) for b in buckets.split("-"))
        return cls(letter, trans, mb, nb, kb)


def size_class(M: int, N: int, K: int, letter: str, trans: str) -> SizeClass:
    return SizeClass(letter, trans, bucket_index(M), bucket_index(N),
                     bucket_index(K))


def representative(sc: SizeClass) -> Tuple[int, int, int]:
    """The (M, N, K) the tuner benchmarks on behalf of the whole class."""
    return (bucket_representative(sc.mb), bucket_representative(sc.nb),
            bucket_representative(sc.kb))


def classes_up_to(letters: Sequence[str], trans: Sequence[str],
                  max_dim: int, min_dim: int = 8,
                  cube_only: bool = False) -> List[SizeClass]:
    """Enumerate the sweep's class grid: every (mb, nb, kb) combination
    whose representatives land in [min_dim, max_dim] (``cube_only``
    restricts to mb == nb == kb, the quick-sweep diagonal).

    Filtering is on the *representative* — the shape actually timed — so
    ``max_dim`` bounds real sweep cost (a bucket whose midpoint
    overshoots max_dim would silently benchmark up to sqrt(GROWTH)
    bigger problems)."""
    ids = [i for i in range(bucket_index(max_dim) + 2)
           if min_dim <= bucket_representative(i) <= max_dim]
    out: List[SizeClass] = []
    for letter in letters:
        for tr in trans:
            for mb in ids:
                for nb in ids:
                    if cube_only and nb != mb:
                        continue
                    for kb in ids:
                        if cube_only and kb != mb:
                            continue
                        out.append(SizeClass(letter, tr, mb, nb, kb))
    return out
