"""repro.tune.online — background traffic-aware re-tuning.

IAAT's run-time stage is *input-aware*: it adapts to the shapes a
deployment actually sees, not to a static offline bucketing.  This
module is that consumer.  :class:`OnlineTuner` periodically folds
``obs.ROUTES.windowed(decay=...)`` — the exponentially-decayed observed
shape distribution the route memo maintains at zero hot-path cost —
into a traffic-weighted priority over size classes, re-times the top-k
hot ones through :func:`repro.tune.search.budgeted_sweep` (the roofline
prior prunes candidates, so a cycle costs at most ``budget`` stopwatch
timings), and merges the delta into the live :class:`DeviceProfile`
via ``merge`` + ``set_active_profile``.  The swap invalidates the
Router's decision memo and emits ``PROFILE_SWAP``, so tuned-mode
dispatch picks the new entries up on its next trace — the engine never
restarts.

Safety story (proved by the differential suite in
``tests/test_serve_fuzz.py``): routing decisions live at jit *trace*
time, so a profile swap can change which kernel a NEW compilation
picks but never the numerics of an already-compiled serving step; and
every entry the tuner installs is a measured pallas/XLA pair, so a
decision flip only ever trades one correct kernel for another.
Routing decisions may change — results may not.

The whole feature sits behind a kill switch: ``REPRO_ONLINE_TUNE=0``
makes :meth:`OnlineTuner.start` a no-op (manual :meth:`cycle` calls
still work, for tests).

Observability: each cycle bumps ``tune.online.cycles`` /
``tune.online.classes_retuned`` / ``tune.online.swaps``, records its
wall time in ``tune.online.cycle_us``, and lands a ``TUNE_CYCLE`` event
(with the cycle duration) in the flight recorder on the tuner's own
Perfetto track.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.tune import classes as classes_mod
from repro.tune.classes import SizeClass
from repro.tune.profile import DeviceProfile, active_profile, \
    current_device_kind, set_active_profile
from repro.tune.search import TuneTarget

__all__ = ["OnlineTuner", "CycleReport", "weighted_targets", "enabled",
           "KILL_SWITCH_ENV"]

KILL_SWITCH_ENV = "REPRO_ONLINE_TUNE"

#: route-log ops that route per-group problems through the grouped
#: kernels (measured by ``tune_grouped_class``, recorded under the
#: profile's ``grouped:`` namespace); everything else re-times as 2-D.
_GROUPED_OPS = ("batched_gemm", "ragged_gemm")


def enabled() -> bool:
    """The ``REPRO_ONLINE_TUNE`` kill switch (default on; only explicit
    off values disable — same parse as ``REPRO_OBS``)."""
    v = os.environ.get(KILL_SWITCH_ENV)
    return (v or "1").strip().lower() not in ("0", "false", "off", "no")


def weighted_targets(folded: Dict[Tuple[str, str, str], float], *,
                     min_weight: float = 1.0,
                     done: Optional[Dict[Tuple[str, str], float]] = None,
                     retune_ratio: float = 1.5,
                     top_k: Optional[int] = None,
                     max_dim: Optional[int] = None) -> List[TuneTarget]:
    """Fold a ``ROUTES.windowed(decay=...)`` dict into a re-tune
    priority list, hottest first.

    ``folded`` maps ``(op, letter, cls)`` to a decayed count.  Ops
    collapse to the measuring ``kind`` ("gemm" for 2-D/ND, "grouped"
    for the batched/ragged paths — their class strings already describe
    the per-group (C, N, K) problem), weights summing across ops of the
    same kind.  Classes below ``min_weight`` are cold traffic — noise,
    not worth a stopwatch.  ``done`` maps ``(kind, class-key)`` to the
    weight at which a class was last tuned: it is skipped until its
    current weight exceeds ``retune_ratio`` times that, so steady
    traffic is tuned once and only a real shift re-tunes (without this
    every cycle would re-burn the budget on the same top-k).
    ``max_dim`` drops classes whose representative exceeds it — the
    cost valve that keeps a huge one-off shape from eating a cycle.
    """
    acc: Dict[Tuple[str, str], Tuple[float, SizeClass]] = {}
    for (op, letter, cls), w in folded.items():
        kind = "grouped" if op in _GROUPED_OPS else "gemm"
        try:
            sc = SizeClass.from_key(f"{letter}/NN/{cls}")
        except (ValueError, TypeError):
            continue
        if max_dim is not None and \
                max(classes_mod.representative(sc)) > max_dim:
            continue
        key = (kind, sc.key)
        prev = acc.get(key)
        acc[key] = (w + (prev[0] if prev else 0.0), sc)
    out: List[TuneTarget] = []
    for (kind, sckey), (w, sc) in acc.items():
        if w < min_weight:
            continue
        if done is not None and w <= retune_ratio * done.get((kind, sckey),
                                                             0.0):
            continue
        out.append(TuneTarget(kind, sc, w))
    out.sort(key=lambda t: (-t.weight, t.kind, t.sc.key))
    return out[:top_k] if top_k is not None else out


@dataclasses.dataclass(frozen=True)
class CycleReport:
    """What one :meth:`OnlineTuner.cycle` did (returned for tests/CLI;
    the same numbers land in the ``tune.online.*`` metrics)."""
    cycle: int
    considered: int            # hot classes that passed the weighter
    retuned: int               # classes actually re-timed this cycle
    timings: int               # stopwatch budget spent
    swapped: bool              # a merged profile went live
    wall_us: float


class OnlineTuner:
    """Background re-tuner: windowed traffic in, live profile swaps out.

    Drive it either way:

    * ``start()`` / ``stop()`` — a daemon thread runs :meth:`cycle`
      every ``interval_s`` seconds; ``stop`` is idempotent, safe to
      call with requests in flight (the engine's compiled steps never
      consult the tuner) and joins the thread with a timeout.
      :class:`repro.serve.PagedEngine` accepts ``tuner=`` and handles
      this lifecycle around ``run()``.
    * ``cycle()`` — one synchronous pass, for tests and CLI use.

    ``sweeper`` injects the measuring stage (same contract as
    ``search.budgeted_sweep``: ``f(targets, budget=) -> (delta_profile,
    tuned, timings)``) so unit tests exercise the weighting/merge/swap
    plumbing without jax timing.
    """

    def __init__(self, *, interval_s: float = 5.0, top_k: int = 4,
                 budget: int = 8, decay: float = 0.5, n_buckets: int = 8,
                 min_weight: float = 1.0, retune_ratio: float = 1.5,
                 top: int = 1, warmup: int = 0, reps: int = 1,
                 interpret: bool = True, grouped_G: int = 4,
                 max_dim: Optional[int] = 1024,
                 device_kind: Optional[str] = None,
                 sweeper: Optional[Callable[..., tuple]] = None,
                 persist: bool = False):
        self.interval_s = interval_s
        self.top_k, self.budget = top_k, budget
        self.decay, self.n_buckets = decay, n_buckets
        self.min_weight, self.retune_ratio = min_weight, retune_ratio
        self.top, self.warmup, self.reps = top, warmup, reps
        self.interpret, self.grouped_G = interpret, grouped_G
        self.max_dim = max_dim
        self.mode = "interpret" if interpret else "compiled"
        self._device_kind = device_kind
        self._sweeper = sweeper
        self.persist = persist
        self.cycles = 0
        self.swaps = 0
        # (kind, class-key) -> traffic weight when last tuned; consulted
        # by the weighter so steady traffic is tuned once per shift
        self._done: Dict[Tuple[str, str], float] = {}
        self._cycle_lock = threading.Lock()     # one cycle at a time
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- one pass ----------------------------------------------------------

    def targets(self) -> List[TuneTarget]:
        """The weighter: current windowed traffic -> re-tune priorities."""
        folded = obs.ROUTES.windowed(self.n_buckets, decay=self.decay)
        return weighted_targets(folded, min_weight=self.min_weight,
                                done=self._done,
                                retune_ratio=self.retune_ratio,
                                top_k=self.top_k, max_dim=self.max_dim)

    def _sweep(self, targets: Sequence[TuneTarget]):
        if self._sweeper is not None:
            return self._sweeper(targets, budget=self.budget)
        from repro.tune import search
        return search.budgeted_sweep(
            targets, budget=self.budget, top=self.top, warmup=self.warmup,
            reps=self.reps, interpret=self.interpret,
            grouped_G=self.grouped_G, device_kind=self._device_kind)

    def _merge_and_swap(self, delta: DeviceProfile) -> bool:
        """Fold the cycle's delta into the live profile and publish it.
        ``merge`` keeps whichever entry measured faster (``better_than``),
        so an online entry only displaces an offline one it beat; the
        publish is ONE ``set_active_profile`` call, which atomically
        replaces the profile object, staleness-bumps the route memo and
        emits ``PROFILE_SWAP``.  Mode/device-kind mismatches (e.g. an
        interpret-mode cycle while a compiled profile is live) skip the
        merge rather than poison comparable timings."""
        base = active_profile()
        if base is not None and len(base):
            if base.device_kind != delta.device_kind \
                    or base.mode != delta.mode:
                obs.counter("tune.online.merge_skips").inc()
                return False
            merged = base.merge(delta)
        else:
            merged = delta
        set_active_profile(merged)
        self.swaps += 1
        obs.counter("tune.online.swaps").inc()
        if self.persist:
            try:
                merged.save()
            except OSError:
                obs.counter("tune.online.persist_failures").inc()
        return True

    def cycle(self) -> CycleReport:
        """One synchronous pass: weigh traffic, re-tune within budget,
        merge + swap.  Serialized — a manual call during a background
        run waits for the in-flight cycle."""
        with self._cycle_lock:
            t0 = time.perf_counter()
            targets = self.targets()
            delta: Optional[DeviceProfile] = None
            tuned: List[TuneTarget] = []
            timings = 0
            if targets:
                delta, tuned, timings = self._sweep(targets)
            swapped = False
            if delta is not None and len(delta):
                swapped = self._merge_and_swap(delta)
            for t in tuned:
                key = (t.kind, t.sc.key)
                self._done[key] = max(t.weight, self._done.get(key, 0.0))
            self.cycles += 1
            wall_us = (time.perf_counter() - t0) * 1e6
            obs.counter("tune.online.cycles").inc()
            if tuned:
                obs.counter("tune.online.classes_retuned").inc(len(tuned))
            obs.histogram("tune.online.cycle_us").record(wall_us)
            obs.TRACE.emit(
                "TUNE_CYCLE",
                arg=(self.cycles, len(tuned), timings, bool(swapped)),
                dur_us=wall_us)
            return CycleReport(self.cycles, len(targets), len(tuned),
                               timings, swapped, wall_us)

    # -- background lifecycle ----------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> bool:
        """Start the background loop; returns False when the
        ``REPRO_ONLINE_TUNE=0`` kill switch is set (tuner stays inert).
        Idempotent — a second start while running is a no-op True."""
        if not enabled():
            return False
        if self.running:
            return True
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-online-tuner",
                                        daemon=True)
        self._thread.start()
        return True

    def _loop(self) -> None:
        # wait FIRST: traffic needs a beat to accumulate, and a
        # stop() right after start() exits without a cycle
        while not self._stop.wait(self.interval_s):
            try:
                self.cycle()
            except Exception:   # noqa: BLE001 — tuning must never kill serving
                obs.counter("tune.online.errors").inc()

    def stop(self, timeout: float = 30.0) -> bool:
        """Signal and join the background loop; True when the thread is
        fully down (always, barring a wedged in-flight cycle).  Safe
        mid-serve and idempotent; the tuner can be start()ed again."""
        t, self._thread = self._thread, None
        if t is None:
            return True
        self._stop.set()
        t.join(timeout)
        return not t.is_alive()

    def __enter__(self) -> "OnlineTuner":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
