"""Persistent per-device tuning profiles.

A :class:`DeviceProfile` is the durable artefact of the empirical
install-time stage: for each measured :class:`SizeClass` it stores the
best pallas kernel signature and the measured pallas/XLA times, from
which dispatch derives both decisions the analytical model used to
guess — *which backend* (the crossover) and *which kernel* (the
per-class override).

Storage is versioned JSON keyed by device kind under an env-var cache
dir (``REPRO_TUNE_CACHE``, default ``~/.cache/repro/tune``), so a
profile tuned once on a v5e pod survives process restarts and is never
misapplied to a different accelerator.  ``merge`` unions two profiles
entry-wise, keeping the better-measured pallas time per class, so
incremental sweeps (one letter today, another tomorrow) compose.

The *active* profile is process-global state consulted by the
``repro.api`` Router whenever a ``Policy(backend="tuned")`` routes any
op — 2-D gemm, ND matmul, or the grouped MoE/serving paths (their
per-group (C, K, N) problem keys the same class table).  It is lazily
loaded from disk on first tuned-mode dispatch and can be
pinned/cleared explicitly by tests and the CLI.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import threading
from typing import Dict, Optional

from repro import obs
from repro.core.kernelgen import KernelSig
from repro.tune.classes import SizeClass, size_class
from repro.tune.timer import Measurement

PROFILE_VERSION = 1
CACHE_ENV = "REPRO_TUNE_CACHE"
_DEFAULT_CACHE = "~/.cache/repro/tune"

#: Entry-key namespace for classes measured on the GROUPED kernels
#: (``batched_gemm``/``ragged_gemm`` time differently from a lone 2-D
#: gemm of the per-group shape: G problems stream through one launch).
#: Entry keys are opaque strings, so the prefix composes with merge,
#: save/load and better_than without a schema bump — old files simply
#: have no ``grouped:`` keys and the router falls back to the 2-D entry.
GROUPED_PREFIX = "grouped:"


def _sig_to_json(sig: KernelSig) -> dict:
    return {"letter": sig.letter, "trans": sig.trans,
            "bm": sig.bm, "bn": sig.bn, "bk": sig.bk}


def _sig_from_json(d: dict) -> KernelSig:
    return KernelSig(d["letter"], d["trans"], int(d["bm"]), int(d["bn"]),
                     int(d["bk"]))


@dataclasses.dataclass(frozen=True)
class ProfileEntry:
    """Measured outcome for one size class."""
    sig: Optional[KernelSig]          # best pallas kernel (None: none ran)
    pallas: Optional[Measurement]
    xla: Optional[Measurement]
    # merge provenance: which stage produced the timing ("sweep" = the
    # offline CLI, "online" = the background re-tuner).  Informational
    # only — merge still keeps whichever entry measured faster, so a
    # newer online entry replaces an offline one iff it is better.
    origin: str = "sweep"

    @property
    def measured(self) -> bool:
        """At least one side actually timed — an all-failed entry carries
        no information and must not override the analytical fallback."""
        return self.pallas is not None or self.xla is not None

    @property
    def prefer_pallas(self) -> bool:
        """The measured crossover: pallas wins this class."""
        if self.sig is None or self.pallas is None:
            return False
        if self.xla is None:
            return True
        return self.pallas.median_us <= self.xla.median_us

    def to_json(self) -> dict:
        return {
            "sig": _sig_to_json(self.sig) if self.sig else None,
            "pallas": self.pallas.to_json() if self.pallas else None,
            "xla": self.xla.to_json() if self.xla else None,
            "origin": self.origin,
        }

    @classmethod
    def from_json(cls, d: dict) -> "ProfileEntry":
        return cls(
            _sig_from_json(d["sig"]) if d.get("sig") else None,
            Measurement.from_json(d["pallas"]) if d.get("pallas") else None,
            Measurement.from_json(d["xla"]) if d.get("xla") else None,
            d.get("origin", "sweep"),      # pre-online files: offline sweep
        )

    def better_than(self, other: "ProfileEntry") -> bool:
        """Merge preference: the entry with the faster measured winner."""
        def best(e: "ProfileEntry") -> float:
            ts = [m.median_us for m in (e.pallas, e.xla) if m is not None]
            return min(ts) if ts else float("inf")
        return best(self) < best(other)


@dataclasses.dataclass
class DeviceProfile:
    device_kind: str
    entries: Dict[str, ProfileEntry] = dataclasses.field(default_factory=dict)
    version: int = PROFILE_VERSION
    # interpret-mode timings are orders of magnitude off compiled ones, so
    # the two never share a file: one profile per (device, mode), and
    # loading prefers compiled (authoritative) over interpret (CI smoke).
    mode: str = "interpret"          # "interpret" | "compiled"

    # -- lookup ------------------------------------------------------------

    def lookup(self, sc: SizeClass) -> Optional[ProfileEntry]:
        return self.entries.get(sc.key)

    def lookup_dims(self, M: int, N: int, K: int, letter: str,
                    trans: str) -> Optional[ProfileEntry]:
        return self.lookup(size_class(M, N, K, letter, trans))

    def record(self, sc: SizeClass, entry: ProfileEntry) -> None:
        self.entries[sc.key] = entry

    # -- grouped-kernel namespace (see GROUPED_PREFIX) ---------------------

    def lookup_grouped(self, sc: SizeClass) -> Optional[ProfileEntry]:
        return self.entries.get(GROUPED_PREFIX + sc.key)

    def lookup_grouped_dims(self, C: int, N: int, K: int,
                            letter: str) -> Optional[ProfileEntry]:
        """Grouped per-group problem (C, K, N) keyed as the (M=C, N, K)
        class; grouped kernels consume operands as stored (trans NN)."""
        return self.lookup_grouped(size_class(C, N, K, letter, "NN"))

    def record_grouped(self, sc: SizeClass, entry: ProfileEntry) -> None:
        self.entries[GROUPED_PREFIX + sc.key] = entry

    def __len__(self) -> int:
        return len(self.entries)

    # -- persistence -------------------------------------------------------

    def to_json(self) -> dict:
        return {"version": self.version, "device_kind": self.device_kind,
                "mode": self.mode,
                "entries": {k: e.to_json() for k, e in
                            sorted(self.entries.items())}}

    @classmethod
    def from_json(cls, d: dict) -> "DeviceProfile":
        ver = int(d.get("version", -1))
        if ver != PROFILE_VERSION:
            raise ValueError(
                f"profile version {ver} != supported {PROFILE_VERSION}; "
                "re-run `python -m repro.tune`")
        return cls(d["device_kind"],
                   {k: ProfileEntry.from_json(e)
                    for k, e in d.get("entries", {}).items()},
                   ver, d.get("mode", "interpret"))

    def save(self, path: Optional[os.PathLike] = None) -> pathlib.Path:
        p = pathlib.Path(path) if path else default_profile_path(
            self.device_kind, self.mode)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(p.suffix + ".tmp")
        tmp.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))
        tmp.replace(p)      # atomic: concurrent readers never see a torn file
        return p

    @classmethod
    def load(cls, path: os.PathLike) -> "DeviceProfile":
        return cls.from_json(json.loads(pathlib.Path(path).read_text()))

    def merge(self, other: "DeviceProfile") -> "DeviceProfile":
        """Entry-wise union; on conflict keep the better-measured entry."""
        if other.device_kind != self.device_kind:
            raise ValueError(f"cannot merge profiles for different devices: "
                             f"{self.device_kind!r} vs {other.device_kind!r}")
        if other.mode != self.mode:
            raise ValueError(f"cannot merge {other.mode!r} timings into a "
                             f"{self.mode!r} profile — not comparable")
        merged = dict(self.entries)
        for k, e in other.entries.items():
            if k not in merged or e.better_than(merged[k]):
                merged[k] = e
        return DeviceProfile(self.device_kind, merged, self.version,
                             self.mode)


# --------------------------------------------------------------------------
# Cache-dir layout.
# --------------------------------------------------------------------------

def cache_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get(CACHE_ENV, "")
                        or _DEFAULT_CACHE).expanduser()


def _sanitize(kind: str) -> str:
    return "".join(c if c.isalnum() or c in "-_." else "_"
                   for c in kind.strip()) or "unknown"


def current_device_kind() -> str:
    import jax
    return _sanitize(jax.devices()[0].device_kind)


def default_profile_path(device_kind: Optional[str] = None,
                         mode: str = "interpret") -> pathlib.Path:
    kind = _sanitize(device_kind) if device_kind else current_device_kind()
    return cache_dir() / f"profile_v{PROFILE_VERSION}_{kind}_{mode}.json"


def find_default_profile() -> Optional[pathlib.Path]:
    """The profile file tuned dispatch would load: compiled timings are
    authoritative when present; an interpret profile (CI smoke) only
    applies when no compiled one exists."""
    for mode in ("compiled", "interpret"):
        p = default_profile_path(mode=mode)
        if p.exists():
            return p
    return None


# --------------------------------------------------------------------------
# The active profile (what tuned-mode dispatch reads).
# --------------------------------------------------------------------------

_UNSET = object()
_active = _UNSET                  # _UNSET: not yet loaded; None: known-absent
_active_lock = threading.Lock()


def _profile_tag(p: Optional[DeviceProfile]) -> Optional[str]:
    return f"{p.device_kind}/{p.mode}:{len(p)}" if p is not None else None


def set_active_profile(p: Optional[DeviceProfile]) -> None:
    global _active
    with _active_lock:
        _active = p
    # decisions memoized by the obs route log may have consulted the old
    # profile — every active-profile transition invalidates them (and is
    # itself a traced event: a swap explains a burst of ROUTE_MISSes)
    obs.ROUTES.invalidate()
    obs.TRACE.emit("PROFILE_SWAP", arg=_profile_tag(p))


def clear_active_profile() -> None:
    """Forget the active profile AND the load attempt (next tuned dispatch
    re-reads disk — call after changing REPRO_TUNE_CACHE or re-tuning)."""
    global _active
    with _active_lock:
        _active = _UNSET
    obs.ROUTES.invalidate()
    obs.TRACE.emit("PROFILE_SWAP", arg=None)


def active_profile() -> Optional[DeviceProfile]:
    """The profile tuned dispatch consults; lazily loaded from the default
    path on first call, None (analytical fallback) if absent/corrupt."""
    global _active
    with _active_lock:
        if _active is _UNSET:
            path = find_default_profile()
            try:
                _active = DeviceProfile.load(path) if path else None
            except (OSError, ValueError, KeyError, json.JSONDecodeError):
                _active = None
            obs.ROUTES.invalidate()
            obs.TRACE.emit("PROFILE_SWAP", arg=_profile_tag(_active))
        return _active
