"""Candidate search: analytical prior first, stopwatch second.

Per size class the search (a) enumerates every legal kernel from the
install-time table, (b) ranks them with the roofline prior — padded-grid
FLOPs vs streamed HBM traffic, the same physics as ``cost.py`` — and
(c) micro-benchmarks only the ``top`` ranked candidates plus the XLA
baseline.  The prior never *decides*, it only prunes: tritonBLAS uses
its analytical model the same way, as a prior that measurements refine,
which keeps sweep cost O(top) per class instead of O(|table|) while the
final word stays empirical.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import cost, kernelgen
from repro.core.kernelgen import KernelSig
from repro.tune import classes as classes_mod
from repro.tune.classes import SizeClass
from repro.tune.profile import DeviceProfile, ProfileEntry, current_device_kind
from repro.tune.timer import Measurement, try_measure


def _cdiv(a: int, b: int) -> int:
    return -(a // -b)


def prior_us(sig: KernelSig, M: int, N: int, K: int) -> float:
    """Roofline estimate (µs) of running the whole problem on one kernel.

    Compute counts the *padded* grid (an oversized block wastes MXU work on
    masked lanes); traffic counts actual per-grid-step panel streaming plus
    the C write-out.  Absolute scale is napkin math; only the ordering is
    consumed, and only as a pruning prior.
    """
    gm, gn, nk = _cdiv(M, sig.bm), _cdiv(N, sig.bn), _cdiv(K, sig.bk)
    item = jnp.dtype(sig.real_dtype).itemsize
    planes = 2 if sig.complex_ else 1
    mults = 3 if sig.complex_ else 1      # karatsuba
    flops = 2.0 * (gm * sig.bm) * (gn * sig.bn) * (nk * sig.bk) * mults
    traffic = (gm * gn * nk * (sig.bm * sig.bk + sig.bk * sig.bn)
               + 2.0 * M * N) * item * planes
    peak = cost.PEAK_FLOPS_F32 / (2 if sig.letter in ("D", "Z") else 1)
    return max(flops / peak, traffic / cost.HBM_BW) * 1e6


def candidates(letter: str, trans: str, M: int, N: int, K: int,
               top: int = 4) -> List[KernelSig]:
    """The ``top`` analytically-promising kernels for this problem."""
    table = kernelgen.kernel_table(letter, trans)
    ranked = sorted(table, key=lambda s: (prior_us(s, M, N, K), s))
    return list(ranked[:max(1, top)])


# --------------------------------------------------------------------------
# Benchmark one size class.
# --------------------------------------------------------------------------

def _operands(sc: SizeClass, M: int, N: int, K: int):
    rng = np.random.RandomState(0x1AA7)
    dt = {**kernelgen.BLAS_DTYPES, **kernelgen.FRAMEWORK_DTYPES}[sc.letter]
    a_shape = (M, K) if sc.trans[0] == "N" else (K, M)
    b_shape = (K, N) if sc.trans[1] == "N" else (N, K)

    def mk(shape):
        x = rng.randn(*shape)
        if kernelgen.IS_COMPLEX.get(sc.letter, False):
            x = x + 1j * rng.randn(*shape)
        return jnp.asarray(x, dt)

    return mk(a_shape), mk(b_shape)


def _xla_fn(trans: str, a, b) -> Callable[[], jax.Array]:
    @jax.jit
    def f(a, b):
        opa = a.T if trans[0] == "T" else a
        opb = b.T if trans[1] == "T" else b
        return jnp.dot(opa, opb)
    return lambda: f(a, b)


def _pallas_fn(sig: KernelSig, a, b, interpret: bool) -> Callable[[], jax.Array]:
    from repro.kernels import iaat_gemm

    @jax.jit
    def f(a, b):
        return iaat_gemm.gemm_region(sig, a, b, None, alpha=1.0, beta=0.0,
                                     interpret=interpret)
    return lambda: f(a, b)


def tune_class(sc: SizeClass, *, top: int = 4, warmup: int = 1,
               reps: int = 5, interpret: bool = True) -> ProfileEntry:
    """Measure one size class at its representative shape; returns the
    entry (best pallas sig + both timings) to record in the profile."""
    M, N, K = classes_mod.representative(sc)
    a, b = _operands(sc, M, N, K)
    xla = try_measure(_xla_fn(sc.trans, a, b), warmup=warmup, reps=reps)
    best_sig: Optional[KernelSig] = None
    best: Optional[Measurement] = None
    for sig in candidates(sc.letter, sc.trans, M, N, K, top=top):
        m = try_measure(_pallas_fn(sig, a, b, interpret),
                        warmup=warmup, reps=reps)
        if m is not None and (best is None or m.median_us < best.median_us):
            best_sig, best = sig, m
    return ProfileEntry(best_sig, best, xla)


def sweep(letters: Sequence[str] = ("S",),
          trans: Sequence[str] = ("NN",), *,
          min_dim: int = 8, max_dim: int = 512, cube_only: bool = False,
          top: int = 4, warmup: int = 1, reps: int = 5,
          interpret: bool = True, device_kind: Optional[str] = None,
          progress: Optional[Callable[[SizeClass, ProfileEntry], None]] = None,
          ) -> DeviceProfile:
    """Run the tuning sweep and return the (unsaved) DeviceProfile."""
    prof = DeviceProfile(device_kind or current_device_kind(),
                         mode="interpret" if interpret else "compiled")
    with obs.span("tune.sweep"):
        for sc in classes_mod.classes_up_to(letters, trans, max_dim,
                                            min_dim=min_dim,
                                            cube_only=cube_only):
            with obs.span("tune.class"):
                entry = tune_class(sc, top=top, warmup=warmup, reps=reps,
                                   interpret=interpret)
            obs.counter("tune.classes_swept").inc()
            prof.record(sc, entry)
            if progress is not None:
                progress(sc, entry)
    return prof
