"""Candidate search: analytical prior first, stopwatch second.

Per size class the search (a) enumerates every legal kernel from the
install-time table, (b) ranks them with the roofline prior — padded-grid
FLOPs vs streamed HBM traffic, the same physics as ``cost.py`` — and
(c) micro-benchmarks only the ``top`` ranked candidates plus the XLA
baseline.  The prior never *decides*, it only prunes: tritonBLAS uses
its analytical model the same way, as a prior that measurements refine,
which keeps sweep cost O(top) per class instead of O(|table|) while the
final word stays empirical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import cost, kernelgen
from repro.core.kernelgen import KernelSig
from repro.tune import classes as classes_mod
from repro.tune.classes import SizeClass
from repro.tune.profile import DeviceProfile, ProfileEntry, current_device_kind
from repro.tune.timer import Measurement, try_measure


def _cdiv(a: int, b: int) -> int:
    return -(a // -b)


def prior_us(sig: KernelSig, M: int, N: int, K: int) -> float:
    """Roofline estimate (µs) of running the whole problem on one kernel.

    Compute counts the *padded* grid (an oversized block wastes MXU work on
    masked lanes); traffic counts actual per-grid-step panel streaming plus
    the C write-out.  Absolute scale is napkin math; only the ordering is
    consumed, and only as a pruning prior.
    """
    gm, gn, nk = _cdiv(M, sig.bm), _cdiv(N, sig.bn), _cdiv(K, sig.bk)
    item = jnp.dtype(sig.real_dtype).itemsize
    planes = 2 if sig.complex_ else 1
    mults = 3 if sig.complex_ else 1      # karatsuba
    flops = 2.0 * (gm * sig.bm) * (gn * sig.bn) * (nk * sig.bk) * mults
    traffic = (gm * gn * nk * (sig.bm * sig.bk + sig.bk * sig.bn)
               + 2.0 * M * N) * item * planes
    peak = cost.PEAK_FLOPS_F32 / (2 if sig.letter in ("D", "Z") else 1)
    return max(flops / peak, traffic / cost.HBM_BW) * 1e6


def candidates(letter: str, trans: str, M: int, N: int, K: int,
               top: int = 4) -> List[KernelSig]:
    """The ``top`` analytically-promising kernels for this problem."""
    table = kernelgen.kernel_table(letter, trans)
    ranked = sorted(table, key=lambda s: (prior_us(s, M, N, K), s))
    return list(ranked[:max(1, top)])


# --------------------------------------------------------------------------
# Benchmark one size class.
# --------------------------------------------------------------------------

def _operands(sc: SizeClass, M: int, N: int, K: int):
    rng = np.random.RandomState(0x1AA7)
    dt = {**kernelgen.BLAS_DTYPES, **kernelgen.FRAMEWORK_DTYPES}[sc.letter]
    a_shape = (M, K) if sc.trans[0] == "N" else (K, M)
    b_shape = (K, N) if sc.trans[1] == "N" else (N, K)

    def mk(shape):
        x = rng.randn(*shape)
        if kernelgen.IS_COMPLEX.get(sc.letter, False):
            x = x + 1j * rng.randn(*shape)
        return jnp.asarray(x, dt)

    return mk(a_shape), mk(b_shape)


def _xla_fn(trans: str, a, b) -> Callable[[], jax.Array]:
    @jax.jit
    def f(a, b):
        opa = a.T if trans[0] == "T" else a
        opb = b.T if trans[1] == "T" else b
        return jnp.dot(opa, opb)
    return lambda: f(a, b)


def _pallas_fn(sig: KernelSig, a, b, interpret: bool) -> Callable[[], jax.Array]:
    from repro.kernels import iaat_gemm

    @jax.jit
    def f(a, b):
        return iaat_gemm.gemm_region(sig, a, b, None, alpha=1.0, beta=0.0,
                                     interpret=interpret)
    return lambda: f(a, b)


def tune_class(sc: SizeClass, *, top: int = 4, warmup: int = 1,
               reps: int = 5, interpret: bool = True) -> ProfileEntry:
    """Measure one size class at its representative shape; returns the
    entry (best pallas sig + both timings) to record in the profile."""
    M, N, K = classes_mod.representative(sc)
    a, b = _operands(sc, M, N, K)
    xla = try_measure(_xla_fn(sc.trans, a, b), warmup=warmup, reps=reps)
    best_sig: Optional[KernelSig] = None
    best: Optional[Measurement] = None
    for sig in candidates(sc.letter, sc.trans, M, N, K, top=top):
        m = try_measure(_pallas_fn(sig, a, b, interpret),
                        warmup=warmup, reps=reps)
        if m is not None and (best is None or m.median_us < best.median_us):
            best_sig, best = sig, m
    return ProfileEntry(best_sig, best, xla)


def tune_grouped_class(sc: SizeClass, *, G: int = 4, top: int = 4,
                       warmup: int = 1, reps: int = 5,
                       interpret: bool = True) -> ProfileEntry:
    """Measure one grouped size class ON the grouped kernel.

    The per-group problem (C, K, N) keys the same class table as 2-D
    gemm (M = C), but G problems stream through one ``batched_gemm``
    launch, so its crossover and best blocks differ from a lone gemm of
    the same shape — this times the real thing instead of reusing the
    2-D entry (the PR-2 leftover).  The XLA side is the batched einsum
    the executor falls back to.
    """
    from repro.kernels import grouped_gemm as _gg
    C, N, K = classes_mod.representative(sc)
    rng = np.random.RandomState(0x1AA7)
    dt = {**kernelgen.BLAS_DTYPES, **kernelgen.FRAMEWORK_DTYPES}[sc.letter]

    def mk(shape):
        x = rng.randn(*shape)
        if kernelgen.IS_COMPLEX.get(sc.letter, False):
            x = x + 1j * rng.randn(*shape)
        return jnp.asarray(x, dt)

    x, w = mk((G, C, K)), mk((G, K, N))

    @jax.jit
    def _einsum(x, w):
        return jnp.einsum("gck,gkn->gcn", x, w)

    xla = try_measure(lambda: _einsum(x, w), warmup=warmup, reps=reps)
    best_sig: Optional[KernelSig] = None
    best: Optional[Measurement] = None
    for sig in candidates(sc.letter, "NN", C, N, K, top=top):

        def _fn(sig=sig):
            @jax.jit
            def f(x, w):
                return _gg.batched_gemm(x, w, interpret=interpret,
                                        blocks=(sig.bm, sig.bn, sig.bk))
            return lambda: f(x, w)

        m = try_measure(_fn(), warmup=warmup, reps=reps)
        if m is not None and (best is None or m.median_us < best.median_us):
            best_sig, best = sig, m
    return ProfileEntry(best_sig, best, xla)


# --------------------------------------------------------------------------
# Budgeted sweep — the online tuner's entry point.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneTarget:
    """One class the online tuner wants re-timed, with its traffic
    weight.  ``kind`` picks the measuring harness: ``"gemm"`` times the
    2-D plan path, ``"grouped"`` times ``batched_gemm`` and records
    under the profile's ``grouped:`` key namespace."""
    kind: str                       # "gemm" | "grouped"
    sc: SizeClass
    weight: float = 0.0


def budgeted_sweep(targets: Sequence[TuneTarget], *, budget: int = 8,
                   top: int = 1, warmup: int = 0, reps: int = 1,
                   interpret: bool = True, grouped_G: int = 4,
                   device_kind: Optional[str] = None,
                   ) -> Tuple[DeviceProfile, List[TuneTarget], int]:
    """Re-tune ``targets`` in order until the timing budget runs out.

    ``budget`` caps the number of stopwatch timings per call (each class
    costs at most ``1 + top``: the baseline plus the prior-pruned pallas
    candidates) so one online cycle's worth of measuring is bounded no
    matter how many classes went hot.  Stops BEFORE starting a class
    that could exceed the budget — a class is either fully timed or not
    touched.  Returns ``(delta_profile, tuned_targets, timings_spent)``;
    the delta holds only the classes actually tuned, ready to merge.
    """
    prof = DeviceProfile(device_kind or current_device_kind(),
                         mode="interpret" if interpret else "compiled")
    per_class = 1 + max(1, top)
    spent = 0
    tuned: List[TuneTarget] = []
    with obs.span("tune.online_sweep"):
        for t in targets:
            if spent + per_class > budget:
                break
            with obs.span("tune.class"):
                if t.kind == "grouped":
                    entry = tune_grouped_class(
                        t.sc, G=grouped_G, top=top, warmup=warmup,
                        reps=reps, interpret=interpret)
                    prof.record_grouped(
                        t.sc, dataclasses.replace(entry, origin="online"))
                else:
                    entry = tune_class(t.sc, top=top, warmup=warmup,
                                       reps=reps, interpret=interpret)
                    prof.record(
                        t.sc, dataclasses.replace(entry, origin="online"))
            obs.counter("tune.classes_swept").inc()
            spent += per_class
            tuned.append(t)
    return prof, tuned, spent


def sweep(letters: Sequence[str] = ("S",),
          trans: Sequence[str] = ("NN",), *,
          min_dim: int = 8, max_dim: int = 512, cube_only: bool = False,
          top: int = 4, warmup: int = 1, reps: int = 5,
          interpret: bool = True, device_kind: Optional[str] = None,
          progress: Optional[Callable[[SizeClass, ProfileEntry], None]] = None,
          ) -> DeviceProfile:
    """Run the tuning sweep and return the (unsaved) DeviceProfile."""
    prof = DeviceProfile(device_kind or current_device_kind(),
                         mode="interpret" if interpret else "compiled")
    with obs.span("tune.sweep"):
        for sc in classes_mod.classes_up_to(letters, trans, max_dim,
                                            min_dim=min_dim,
                                            cube_only=cube_only):
            with obs.span("tune.class"):
                entry = tune_class(sc, top=top, warmup=warmup, reps=reps,
                                   interpret=interpret)
            obs.counter("tune.classes_swept").inc()
            prof.record(sc, entry)
            if progress is not None:
                progress(sc, entry)
    return prof
