"""Micro-benchmark harness for the empirical tuner.

Robustness over cleverness: dispatch overhead and the first-call compile
are excluded by ``warmup`` calls, async dispatch is closed out with
``jax.block_until_ready`` on the full result tree, and the reported
statistic is the *median* of k repeats (immune to one GC pause or
preemption, unlike mean; less optimistic than min when the device is
shared).  In the CPU container kernels run under Pallas interpret mode —
absolute numbers are meaningless there but the harness still produces a
total order, which is all the tuner needs, and ``Measurement.reliable``
flags how trustworthy that order is (spread of the repeats).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro import obs


@dataclasses.dataclass(frozen=True)
class Measurement:
    median_us: float
    best_us: float
    worst_us: float
    reps: int

    @property
    def reliable(self) -> bool:
        """Repeats agree to within 4x — enough to trust a ranking."""
        return self.worst_us <= 4 * self.best_us

    def to_json(self) -> dict:
        return {"median_us": self.median_us, "best_us": self.best_us,
                "worst_us": self.worst_us, "reps": self.reps}

    @classmethod
    def from_json(cls, d: dict) -> "Measurement":
        return cls(float(d["median_us"]), float(d["best_us"]),
                   float(d["worst_us"]), int(d["reps"]))


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def measure(fn: Callable[[], Any], *, warmup: int = 1,
            reps: int = 5) -> Measurement:
    """Time ``fn()`` (which must return a jax array / pytree): median-of-k
    wall microseconds after ``warmup`` discarded calls."""
    if reps < 1:
        raise ValueError("reps must be >= 1")
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    m = Measurement(_median(times), min(times), max(times), reps)
    # sweep provenance: how many candidates were timed, how long each
    # took, and how many rankings are trustworthy — exported alongside
    # the profile so a BENCH file records where its numbers came from
    obs.counter("tune.measurements").inc()
    obs.histogram("tune.measure_us").record(m.median_us)
    if not m.reliable:
        obs.counter("tune.unreliable").inc()
    return m


def try_measure(fn: Callable[[], Any], *, warmup: int = 1,
                reps: int = 5) -> Optional[Measurement]:
    """``measure`` but a failing candidate (lowering error, OOM, interpret
    limitation) yields None instead of aborting the whole sweep."""
    try:
        return measure(fn, warmup=warmup, reps=reps)
    except Exception:  # noqa: BLE001 — any candidate failure disqualifies it
        obs.counter("tune.failures").inc()
        return None
