import os
import sys

# kernels tests need f64 (DGEMM/ZGEMM parity with the paper)
os.environ.setdefault("JAX_ENABLE_X64", "True")
# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benches must see exactly 1 device (the dry-run sets its own flag).

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
