"""repro.api: the unified Policy + Router covering every GEMM shape.

Covers the PR-2 acceptance criteria:
* route() source precedence (forced > profile > analytical) per op kind,
* ND matmul shape/grad parity vs jnp.matmul (including under jax.vmap),
* DeviceProfile entries demonstrably changing the blocks grouped GEMM
  uses (vs the analytical pick_blocks fallback when no profile exists),
* the XLA/pallas epilogues agreeing on the output dtype for any c dtype,
* the traditional (pack-step) baseline agreeing with the routed path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import Decision, Policy, Router
from repro.core import dispatch
from repro.core.kernelgen import KernelSig
from repro.kernels import grouped_gemm, ops
from repro.models import common
from repro.tune import classes, profile as profile_mod
from repro.tune.profile import DeviceProfile, ProfileEntry
from repro.tune.timer import Measurement


@pytest.fixture(autouse=True)
def _isolated_profile_state(tmp_path, monkeypatch):
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    profile_mod.clear_active_profile()
    yield
    profile_mod.clear_active_profile()


def _entry(pallas_us, xla_us, sig=KernelSig("S", "NN", 64, 128, 128)):
    m = lambda us: Measurement(us, us * 0.9, us * 1.1, 3)  # noqa: E731
    return ProfileEntry(sig, m(pallas_us), m(xla_us))


def _activate(M, N, K, pallas_us, xla_us, sig, letter="S", trans="NN"):
    prof = DeviceProfile(profile_mod.current_device_kind())
    prof.record(classes.size_class(M, N, K, letter, trans),
                _entry(pallas_us, xla_us, sig=sig))
    profile_mod.set_active_profile(prof)
    return prof


# -- Policy ----------------------------------------------------------------

def test_policy_is_frozen_and_replaceable():
    p = Policy()
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.backend = "xla"
    assert p.replace(backend="xla").backend == "xla"
    assert p.backend == "auto"


def test_policy_kernel_family_derivation():
    assert Policy(backend="xla").kind == "xla"
    assert not Policy(backend="xla").pallas
    for b in ("auto", "pallas", "tuned"):
        assert Policy(backend=b).pallas
    # explicit pin beats derivation (the old two-axis Backend)
    assert Policy(backend="auto", kernels="xla").kind == "xla"


def test_ambient_policy_install_and_using():
    base = api.current_policy()
    try:
        api.install(Policy(backend="tuned", method="greedy"))
        assert api.current_policy().backend == "tuned"
        with api.using(backend="xla"):
            assert api.current_policy().backend == "xla"
            assert api.current_policy().method == "greedy"  # layered
        assert api.current_policy().backend == "tuned"
    finally:
        api.install(base)


def test_named_policy_covers_cli_surface():
    assert api.named_policy("xla") == common.XLA
    assert api.named_policy("pallas") == common.PALLAS_INTERPRET
    assert api.named_policy("tuned").backend == "tuned"
    with pytest.raises(ValueError):
        api.named_policy("cuda")


# -- Router: precedence per op kind ----------------------------------------

@pytest.mark.parametrize("op,dims", [
    ("gemm", (45, 45, 45)),
    ("matmul", (3, 15, 45, 45)),
    ("batched_gemm", (8, 45, 45, 45)),
    ("ragged_gemm", (8, 128, 45, 45)),
])
def test_route_source_precedence(op, dims):
    sig = KernelSig("S", "NN", 32, 128, 256)
    # the profile class keyed by the per-group/2-D problem of `dims`
    if op == "gemm":
        M, N, K = dims
    elif op == "matmul":
        M, N, K = dims[0] * dims[1], dims[-1], dims[-2]
    else:
        M, N, K = dims[1], dims[3], dims[2]
    _activate(M, N, K, pallas_us=1.0, xla_us=100.0, sig=sig)

    forced = api.route(op, dims, "S", policy=Policy(backend="pallas"))
    assert forced.source == "forced" and forced.use_pallas
    assert api.route(op, dims, "S",
                     policy=Policy(backend="xla")).source == "forced"
    prof = api.route(op, dims, "S", policy=Policy(backend="tuned"))
    assert prof.source == "profile" and prof.use_pallas
    assert prof.sig == sig
    profile_mod.clear_active_profile()
    ana = api.route(op, dims, "S", policy=Policy(backend="tuned"))
    assert ana.source == "analytical"       # tuned degrades, never strands
    assert ana == api.route(op, dims, "S", policy=Policy(backend="auto"))
    assert ana.op == op                     # source inspectable per op kind


def test_route_profile_says_xla_wins():
    _activate(45, 45, 45, pallas_us=100.0, xla_us=1.0,
              sig=KernelSig("S", "NN", 32, 128, 256))
    d = api.route("gemm", (45, 45, 45), "S", policy=Policy(backend="tuned"))
    assert d.source == "profile" and not d.use_pallas


def test_route_rejects_unknown_op():
    with pytest.raises(ValueError):
        api.route("conv", (4, 4, 4), "S")


def test_router_pins_policy():
    r = Router(Policy(backend="xla"))
    assert r.route("gemm", (8, 8, 8), "S").source == "forced"
    # an unpinned Router follows the ambient policy
    with api.using(backend="pallas"):
        assert Router().route("gemm", (8, 8, 8), "S").use_pallas


# -- grouped block selection: profile-steered vs analytical -----------------

def test_batched_gemm_blocks_profile_vs_fallback():
    """The acceptance check: a DeviceProfile entry demonstrably changes
    the blocks batched_gemm uses; without one, pick_blocks decides."""
    G, C, K, N = 4, 45, 200, 300
    analytical = grouped_gemm.pick_blocks(C, K, N, jnp.float32)
    no_prof = api.route("batched_gemm", (G, C, K, N), jnp.float32,
                        policy=Policy(backend="tuned"))
    assert no_prof.source == "analytical"
    assert no_prof.blocks == analytical

    sig = KernelSig("S", "NN", 16, 256, 512)
    assert (sig.bm, sig.bn, sig.bk) != analytical
    _activate(C, N, K, pallas_us=1.0, xla_us=100.0, sig=sig)
    tuned = api.route("batched_gemm", (G, C, K, N), jnp.float32,
                      policy=Policy(backend="tuned"))
    assert tuned.source == "profile"
    assert tuned.blocks == (sig.bm, sig.bn, sig.bk)
    assert tuned.blocks != no_prof.blocks

    # and the executor actually computes the right thing with them
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(G, C, K), jnp.float32)
    w = jnp.asarray(rng.randn(G, K, N), jnp.float32)
    out = api.batched_gemm(x, w, policy=Policy(backend="tuned"))
    np.testing.assert_allclose(np.asarray(out),
                               np.einsum("gck,gkn->gcn", np.asarray(x),
                                         np.asarray(w)),
                               rtol=2e-4, atol=2e-3)


def test_profile_changes_blocks_the_kernel_actually_uses(monkeypatch):
    """End-to-end acceptance: the blocks handed to the Pallas grouped
    kernels (not just the route() answer) flip when a profile appears."""
    G, C, K, N = 2, 45, 200, 300
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(G, C, K), jnp.float32)
    w = jnp.asarray(rng.randn(G, K, N), jnp.float32)
    seen = []
    real = grouped_gemm.batched_gemm

    def spy(x, w, *, interpret=True, blocks=None):
        seen.append(blocks)
        return real(x, w, interpret=interpret, blocks=blocks)

    monkeypatch.setattr(grouped_gemm, "batched_gemm", spy)
    pol = Policy(backend="tuned")
    api.batched_gemm(x, w, policy=pol)          # no profile: analytical
    sig = KernelSig("S", "NN", 16, 256, 512)
    _activate(C, N, K, pallas_us=1.0, xla_us=100.0, sig=sig)
    api.batched_gemm(x, w, policy=pol)          # profile: measured blocks
    assert seen[0] == grouped_gemm.pick_blocks(C, K, N, jnp.float32)
    assert seen[1] == (sig.bm, sig.bn, sig.bk)
    assert seen[0] != seen[1]

    # ragged path: same flip, row block pinned
    seen_r = []
    real_r = grouped_gemm.ragged_gemm

    def spy_r(x, w, gids, *, bm=128, interpret=True, blocks=None):
        seen_r.append(blocks)
        return real_r(x, w, gids, bm=bm, interpret=interpret, blocks=blocks)

    monkeypatch.setattr(grouped_gemm, "ragged_gemm", spy_r)
    bm = 128
    xr = jnp.asarray(rng.randn(G * bm, K), jnp.float32)
    gids = jnp.asarray([0, 1], jnp.int32)
    profile_mod.clear_active_profile()
    api.ragged_gemm(xr, w, gids, bm=bm, policy=pol)
    _activate(bm, N, K, pallas_us=1.0, xla_us=100.0, sig=sig)
    api.ragged_gemm(xr, w, gids, bm=bm, policy=pol)
    assert seen_r[0] == (bm,) + grouped_gemm.pick_blocks(
        bm, K, N, jnp.float32)[1:]
    assert seen_r[1] == (bm, sig.bn, sig.bk)
    assert seen_r[0] != seen_r[1]


def test_ragged_gemm_blocks_keep_caller_row_block():
    G, bm, K, N = 4, 128, 200, 300
    sig = KernelSig("S", "NN", 16, 256, 512)
    _activate(bm, N, K, pallas_us=1.0, xla_us=100.0, sig=sig)
    d = api.route("ragged_gemm", (G, bm, K, N), jnp.float32,
                  policy=Policy(backend="tuned"))
    assert d.source == "profile"
    assert d.blocks == (bm, sig.bn, sig.bk)   # bm pinned: sizes are traced


def test_ops_batched_gemm_resolves_blocks_via_router():
    """kernels.ops grouped entries consult the router when blocks=None."""
    G, C, K, N = 2, 16, 32, 128
    sig = KernelSig("S", "NN", 8, 128, 128)
    _activate(C, N, K, pallas_us=1.0, xla_us=100.0, sig=sig)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(G, C, K), jnp.float32)
    w = jnp.asarray(rng.randn(G, K, N), jnp.float32)
    with api.using(backend="tuned"):
        out = ops.batched_gemm(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.einsum("gck,gkn->gcn", np.asarray(x),
                                         np.asarray(w)),
                               rtol=2e-4, atol=2e-3)


def test_grouped_xla_fallbacks_match_einsum():
    G, C, K, N = 3, 16, 24, 40
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(G, C, K), jnp.float32)
    w = jnp.asarray(rng.randn(G, K, N), jnp.float32)
    pol = Policy(backend="xla")
    out = api.batched_gemm(x, w, policy=pol)
    np.testing.assert_allclose(np.asarray(out),
                               np.einsum("gck,gkn->gcn", np.asarray(x),
                                         np.asarray(w)),
                               rtol=1e-5, atol=1e-5)
    # ragged xla fallback: 2 groups x bm rows each
    bm = 8
    xr = jnp.asarray(rng.randn(2 * bm, K), jnp.float32)
    gids = jnp.asarray([0, 1], jnp.int32)
    outr = api.ragged_gemm(xr, w[:2], gids, bm=bm, policy=pol)
    want = np.concatenate([np.asarray(xr[:bm]) @ np.asarray(w[0]),
                           np.asarray(xr[bm:]) @ np.asarray(w[1])])
    np.testing.assert_allclose(np.asarray(outr), want, rtol=1e-5)
    # and the pallas path agrees with the fallback
    outp = api.ragged_gemm(xr, w[:2], gids, bm=bm,
                           policy=Policy(backend="pallas"))
    np.testing.assert_allclose(np.asarray(outp), want, rtol=2e-4,
                               atol=2e-3)


# -- ND matmul: shape + grad parity, vmap-safety ----------------------------

@pytest.mark.parametrize("lead", [(), (4,), (2, 3), (2, 2, 2)])
def test_matmul_nd_parity(lead):
    rng = np.random.RandomState(0)
    K, N = 24, 40
    x = jnp.asarray(rng.randn(*lead, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    for pol in (Policy(backend="pallas"), Policy(backend="auto"),
                Policy(backend="xla")):
        out = api.matmul(x, w, policy=pol)
        assert out.shape == lead + (N,)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.matmul(x, w)),
                                   rtol=2e-4, atol=2e-4)


def test_matmul_grad_parity():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 5, 16), jnp.float32)
    w = jnp.asarray(rng.randn(16, 24), jnp.float32)
    pol = Policy(backend="pallas", interpret=True)

    def f_iaat(x, w):
        return jnp.sum(api.matmul(x, w, policy=pol) ** 2)

    def f_ref(x, w):
        return jnp.sum(jnp.matmul(x, w) ** 2)

    gx, gw = jax.grad(f_iaat, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               rtol=2e-4, atol=2e-3)


def test_matmul_under_vmap():
    rng = np.random.RandomState(4)
    xs = jnp.asarray(rng.randn(6, 5, 16), jnp.float32)
    w = jnp.asarray(rng.randn(16, 24), jnp.float32)
    pol = Policy(backend="pallas", interpret=True)
    out = jax.vmap(lambda x: api.matmul(x, w, policy=pol))(xs)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.matmul(xs, w)),
                               rtol=2e-4, atol=2e-4)
    # vmap-of-grad, the training shape
    g = jax.vmap(jax.grad(
        lambda x: jnp.sum(api.matmul(x, w, policy=pol) ** 2)))(xs)
    gr = jax.vmap(jax.grad(
        lambda x: jnp.sum(jnp.matmul(x, w) ** 2)))(xs)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=2e-4, atol=2e-3)


def test_matmul_iaat_false_bypasses_router():
    x = jnp.ones((3, 4, 8), jnp.float32)
    w = jnp.ones((8, 16), jnp.float32)
    out = api.matmul(x, w, policy=Policy(backend="pallas", iaat=False))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.matmul(x, w)))


# -- epilogue dtype agreement (satellite) -----------------------------------

@pytest.mark.parametrize("c_dtype", [jnp.float32, jnp.bfloat16])
def test_xla_and_pallas_epilogue_dtype_agree(c_dtype):
    """beta*c with a c of ANY dtype must not promote/demote the output,
    and beta must apply at accumulator precision (NOT c.dtype — the old
    XLA epilogue cast beta into bf16 when c was bf16): both epilogues
    cast c into the accumulator, then to result_type(a, b)."""
    rng = np.random.RandomState(5)
    alpha, beta = 1.5, 0.3            # 0.3 is inexact in bf16
    a = jnp.asarray(rng.randn(16, 12), jnp.float32)
    b = jnp.asarray(rng.randn(12, 20), jnp.float32)
    c = jnp.asarray(rng.randn(16, 20), c_dtype)
    out_x = api.gemm(a, b, c, alpha=alpha, beta=beta,
                     policy=Policy(backend="xla"))
    out_p = api.gemm(a, b, c, alpha=alpha, beta=beta,
                     policy=Policy(backend="pallas", interpret=True))
    assert out_x.dtype == jnp.result_type(a.dtype, b.dtype)
    assert out_p.dtype == out_x.dtype
    want = (alpha * np.asarray(a, np.float64) @ np.asarray(b, np.float64)
            + beta * np.asarray(c.astype(jnp.float32), np.float64))
    np.testing.assert_allclose(np.asarray(out_x), want, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_p), want, rtol=1e-4,
                               atol=1e-4)


# -- post-shim surface ------------------------------------------------------

def test_shims_are_gone():
    """PR-6 housekeeping: the deprecation shims were removed for real."""
    from repro.kernels import ops
    for mod, name in ((dispatch, "DispatchConfig"), (dispatch, "configure"),
                      (dispatch, "decide"), (dispatch, "iaat_gemm"),
                      (common, "Backend"), (ops, "gemm_jit")):
        assert not hasattr(mod, name), f"{mod.__name__}.{name} still exists"


def test_traditional_baseline_matches_routed_path():
    """The surviving dispatch module is the pack-step baseline only, and
    it agrees numerically with the routed pallas path."""
    rng = np.random.RandomState(7)
    a = jnp.asarray(rng.randn(24, 16), jnp.float32)
    b = jnp.asarray(rng.randn(16, 20), jnp.float32)
    trad = dispatch.traditional_gemm(a, b, interpret=True)
    routed = api.gemm(a, b, policy=Policy(backend="pallas", interpret=True))
    np.testing.assert_allclose(np.asarray(trad), np.asarray(routed),
                               rtol=2e-5, atol=1e-4)
    assert dispatch.traditional_pack_bytes(45, 77, 33, jnp.float32) > 0


def test_mm_uses_ambient_policy():
    x = jnp.ones((2, 3, 8), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    with api.using(backend="xla", iaat=False):
        out = common.mm(x, w)            # no explicit be: ambient policy
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.matmul(x, w)))
