"""Unit tests: templates, VMEM allocator, kernel generator, dispatch."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip
from hypothesis import given, settings, strategies as st

from repro import api
from repro.core import kernelgen, paper_table, templates, vmem


def test_contract_all_transpositions():
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(8, 16), jnp.float32)   # (M, K) / (K, M)
    b = jnp.asarray(rng.randn(16, 8), jnp.float32)
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(templates.contract(a, b, "NN"), want, rtol=1e-5)
    np.testing.assert_allclose(templates.contract(a.T, b, "TN"), want, rtol=1e-5)
    np.testing.assert_allclose(templates.contract(a, b.T, "NT"), want, rtol=1e-5)
    np.testing.assert_allclose(templates.contract(a.T, b.T, "TT"), want, rtol=1e-5)


def test_karatsuba_equals_fcmla():
    rng = np.random.RandomState(1)
    ar, ai = (jnp.asarray(rng.randn(4, 8), jnp.float32) for _ in range(2))
    br, bi = (jnp.asarray(rng.randn(8, 4), jnp.float32) for _ in range(2))
    p1, p2, p3 = templates.cmul_karatsuba(ar, ai, br, bi, "NN")
    kr, ki = templates.karatsuba_combine(p1, p2, p3)
    fr, fi = templates.cmul_fcmla(ar, ai, br, bi, "NN")
    np.testing.assert_allclose(np.asarray(kr), np.asarray(fr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ki), np.asarray(fi), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=100, deadline=None)
@given(st.sampled_from([8, 16, 64, 256, 512]),
       st.sampled_from([128, 256, 512]),
       st.sampled_from([128, 512, 2048]),
       st.sampled_from(["float32", "bfloat16"]))
def test_footprint_monotone_and_positive(bm, bn, bk, dtype):
    fp = vmem.footprint(bm, bn, bk, dtype)
    assert fp.total > 0
    fp2 = vmem.footprint(bm * 2, bn, bk, dtype)
    assert fp2.total > fp.total


def test_vmem_budget_honored_by_table():
    for sig in kernelgen.full_table():
        assert sig.footprint().fits, sig


def test_table_counts_nonempty_and_tn_reduced():
    c = kernelgen.census()
    assert all(v > 0 for v in c.values())
    # TN families are smaller, mirroring the paper's observation
    assert c["SGEMM_TN"] < c["SGEMM_NN"]


def test_armv8_census_hundreds():
    assert paper_table.total_kernels() == 786   # 'hundreds of kernels'


def test_smallness_criterion_paper_values():
    with api.using(paper_thresholds=True):
        assert api.small_enough(80, 80, 80, "NN")
        assert not api.small_enough(81, 81, 81, "NN")
        assert api.small_enough(32, 32, 32, "TN")
        assert not api.small_enough(33, 33, 33, "TN")


def test_align_helpers():
    assert vmem.align_m(1, jnp.float32) == 8
    assert vmem.align_m(9, jnp.bfloat16) == 16
    assert vmem.align_n(1, jnp.float32) == 128
    assert vmem.align_k(129, jnp.float32) == 256


def test_whole_problem_vmem_bound():
    n32 = vmem.max_whole_problem(jnp.float32)
    assert 256 <= n32 <= 1024    # sanity: a few hundred fits VMEM
    assert vmem.max_whole_problem(jnp.float32, complex_=True) < n32


def test_build_kernel_cache():
    sig = kernelgen.kernel_table("S", "NN")[0]
    k1 = kernelgen.build_kernel(sig, interpret=True)
    k2 = kernelgen.build_kernel(sig, interpret=True)
    assert k1 is k2


def test_install_subset():
    n = kernelgen.install(letters=("D",), trans=("TT",), interpret=True,
                          max_per_family=5)
    assert n == 5
