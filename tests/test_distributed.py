"""Multi-device integration: a REAL sharded train step on 8 fake CPU
devices (subprocess so the device-count flag never leaks into other
tests), checking (a) it runs, (b) loss matches the single-device run."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os, json, sys
if os.environ.get("FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["FAKE_DEVICES"])
import jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.models.registry import build
from repro.parallel import rules as R
from repro.parallel.ctx import activation_axes, activation_sharding
from repro.train import loop as TL, data as data_mod

cfg = configs.get_smoke("moonshot-v1-16b-a3b")
model = build(cfg)
if os.environ.get("FAKE_DEVICES"):
    mesh = make_mesh((2, 4), ("data", "model"))
else:
    mesh = make_mesh((1, 1), ("data", "model"))
rules = R.make_rules(cfg, mesh)
tc = TL.TrainConfig(accum_steps=2)
step_fn = TL.make_train_step(model, tc, __import__(
    "repro.models.common", fromlist=["XLA"]).XLA)
state_sh = rules.tree_shardings(TL.train_state_specs(model))
shape = ShapeConfig("t", 32, 4, "train")
data_sh = R.data_shardings(cfg, shape, mesh, rules)
data = data_mod.SyntheticTokens(cfg.vocab, 32, 4, seed=11)
act = activation_axes(cfg, mesh, R.batch_spec(mesh, 4))
with mesh, activation_sharding(mesh, act):
    state = jax.jit(lambda k: TL.init_train_state(model, k),
                    out_shardings=state_sh)(jax.random.PRNGKey(0))
    step = jax.jit(step_fn, in_shardings=(state_sh, None),
                   out_shardings=(state_sh, None))
    losses = []
    for s in range(3):
        gb = data_mod.make_global_batch(data.batch(s), data_sh)
        state, m = step(state, gb)
        losses.append(float(m["loss"]))
print(json.dumps({"losses": losses, "ndev": jax.device_count()}))
"""


def _run(fake_devices: str):
    env = dict(os.environ)
    env["FAKE_DEVICES"] = fake_devices
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    multi = _run("8")
    single = _run("")
    assert multi["ndev"] == 8
    assert single["ndev"] == 1
    for a, b in zip(multi["losses"], single["losses"]):
        assert abs(a - b) / max(abs(b), 1e-6) < 5e-2, (multi, single)
    # loss is finite and decreasing-ish over 3 steps is not guaranteed,
    # but it must be finite
    assert all(abs(x) < 1e4 for x in multi["losses"])
