"""Per-kernel allclose vs ref.py oracle: IAAT GEMM, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip
from hypothesis import given, settings, strategies as st

from repro import api
from repro.core import dispatch, kernelgen, plan as plan_mod
from repro.kernels import iaat_gemm, ref

jax.config.update("jax_enable_x64", True)

_RTOL = {"S": 2e-5, "D": 1e-12, "C": 2e-4, "Z": 1e-12, "H": 2e-2}


def _mk(rng, shape, letter):
    dt = kernelgen.BLAS_DTYPES.get(letter, jnp.bfloat16)
    x = rng.randn(*shape)
    if letter in ("C", "Z"):
        x = x + 1j * rng.randn(*shape)
    return jnp.asarray(x, dt)


def _run_case(letter, trans, M, N, K, alpha, beta, rng):
    a_shape = (M, K) if trans[0] == "N" else (K, M)
    b_shape = (K, N) if trans[1] == "N" else (N, K)
    a, b = _mk(rng, a_shape, letter), _mk(rng, b_shape, letter)
    c = _mk(rng, (M, N), letter) if beta else None
    with api.using(backend="pallas", interpret=True):
        out = api.gemm(a, b, c, alpha, beta,
                       trans[0] == "T", trans[1] == "T")
    want = ref.ref_gemm(a, b, c, alpha, beta,
                        trans[0] == "T", trans[1] == "T")
    tol = _RTOL[letter]
    np.testing.assert_allclose(np.asarray(out, np.complex128 if letter in
                                          ("C", "Z") else np.float64),
                               np.asarray(want, np.complex128 if letter in
                                          ("C", "Z") else np.float64),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("letter", ["S", "D", "C", "Z"])
@pytest.mark.parametrize("trans", ["NN", "NT", "TN", "TT"])
def test_all_families_small(letter, trans):
    """Paper TABLE I coverage: every (dtype x transposition) family."""
    rng = np.random.RandomState(hash((letter, trans)) % 2**31)
    _run_case(letter, trans, 30, 50, 21, 1.5 if letter in "SD" else 1.5 + 0.5j,
              0.5 if letter in "SD" else 0.25 - 1j, rng)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 140), st.integers(1, 140), st.integers(1, 140),
       st.sampled_from(["NN", "NT", "TN", "TT"]))
def test_sgemm_shape_sweep(M, N, K, trans):
    """Property: planned-kernel GEMM == oracle for arbitrary shapes."""
    rng = np.random.RandomState(M * 10007 + N * 101 + K)
    _run_case("S", trans, M, N, K, 1.0, 0.0, rng)


@pytest.mark.parametrize("M,N,K", [(1, 1, 1), (8, 128, 128), (129, 257, 130),
                                   (5, 3, 200), (512, 512, 512)])
def test_sgemm_edge_shapes(M, N, K):
    rng = np.random.RandomState(0)
    _run_case("S", "NN", M, N, K, 1.0, 0.0, rng)


def test_alpha_beta_fused_epilogue():
    rng = np.random.RandomState(1)
    _run_case("S", "NN", 40, 40, 40, -0.75, 2.5, rng)
    _run_case("Z", "TT", 12, 9, 7, 1 - 2j, -0.5j, rng)


def test_kernel_region_direct():
    """A single generated kernel handles multi-block grids + K tails."""
    rng = np.random.RandomState(2)
    sig = kernelgen.KernelSig("S", "NN", 8, 128, 128)
    a = jnp.asarray(rng.randn(20, 300), jnp.float32)
    b = jnp.asarray(rng.randn(300, 140), jnp.float32)
    out = iaat_gemm.gemm_region(sig, a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b),
                               rtol=2e-5, atol=1e-4)


def test_dispatch_large_falls_through_to_xla():
    rng = np.random.RandomState(3)
    a = jnp.asarray(rng.randn(600, 600), jnp.float32)
    b = jnp.asarray(rng.randn(600, 600), jnp.float32)
    with api.using(backend="auto", interpret=True):
        assert not api.small_enough(600, 600, 600)
        out = api.gemm(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_gemm(a, b)), rtol=2e-5,
                               atol=1e-4)


def test_traditional_pack_path_matches():
    rng = np.random.RandomState(4)
    a = jnp.asarray(rng.randn(33, 44), jnp.float32)
    b = jnp.asarray(rng.randn(44, 55), jnp.float32)
    out = dispatch.traditional_gemm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_gemm(a, b)), rtol=2e-5,
                               atol=1e-4)


def test_plan_region_count_small_problem():
    """Small problems should need very few kernel launches."""
    p = plan_mod.build_plan(64, 128, 64, "S", "NN")
    assert p.num_kernel_calls == 1
    p2 = plan_mod.build_plan(80, 80, 80, "S", "NN")
    assert p2.num_kernel_calls <= 2
