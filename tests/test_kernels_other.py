"""Per-kernel allclose vs ref.py: flash attention, grouped GEMM, SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# -- flash attention -------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 24])
def test_flash_vs_ref(causal, window):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 4, 80, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 80, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 80, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bkv=32, interpret=True)
    want = ref.ref_mha(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([(4, 1), (4, 2), (6, 3)]),
       st.integers(17, 97), st.sampled_from([16, 32, 64]))
def test_flash_shape_sweep(B, heads, S, D):
    Hq, Hkv = heads
    rng = np.random.RandomState(B * 7 + S)
    q = jnp.asarray(rng.randn(B, Hq, S, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bkv=32,
                              interpret=True)
    want = ref.ref_mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_step():
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(2, 4, 1, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 64, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 64, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=63, bq=8,
                              bkv=32, interpret=True)
    want = ref.ref_mha(q, k, v, causal=True, q_offset=63)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_mha_oracle_consistency():
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(1, 2, 50, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 50, 16), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 50, 16), jnp.float32)
    for window in (None, 13):
        a = ref.chunked_mha(q, k, v, causal=True, window=window, kv_chunk=16)
        b = ref.ref_mha(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


# -- grouped GEMM ----------------------------------------------------------

def test_batched_gemm():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(4, 24, 96), jnp.float32)
    w = jnp.asarray(rng.randn(4, 96, 56), jnp.float32)
    out = ops.batched_gemm(x, w, interpret=True)
    want = jnp.einsum("gck,gkn->gcn", x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=2, max_size=5),
       st.sampled_from([32, 96]), st.sampled_from([48, 128]))
def test_ragged_gemm_property(sizes, K, N):
    bm = 8
    G = len(sizes)
    rng = np.random.RandomState(sum(sizes) + K)
    w = jnp.asarray(rng.randn(G, K, N), jnp.float32)
    xs, gids, want_rows = [], [], []
    for g, s in enumerate(sizes):
        p = max(-(s // -bm) * bm, bm)
        blk = rng.randn(p, K).astype(np.float32)
        blk[s:] = 0
        xs.append(blk)
        gids += [g] * (p // bm)
        want_rows.append(blk @ np.asarray(w[g]))
    x = jnp.asarray(np.concatenate(xs), jnp.float32)
    out = ops.ragged_gemm(x, w, jnp.asarray(np.array(gids, np.int32)),
                          bm=bm, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.concatenate(want_rows),
                               rtol=2e-5, atol=2e-4)


# -- Mamba-2 SSD -----------------------------------------------------------

def _ssd_inputs(rng, Bt, S, H, P, N):
    x = jnp.asarray(rng.randn(Bt, S, H, P) * 0.3, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(Bt, S, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(H)) * 0.5 - 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(Bt, S, 1, N) * 0.3, jnp.float32)
    C = jnp.asarray(rng.randn(Bt, S, 1, N) * 0.3, jnp.float32)
    return x, dt, A, B, C


def test_ssd_chunked_vs_recurrent():
    rng = np.random.RandomState(4)
    x, dt, A, B, C = _ssd_inputs(rng, 2, 96, 3, 16, 24)
    gt = ref.ref_ssd_recurrent(x, dt, A, B, C)
    ck = ref.ref_ssd(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(gt),
                               rtol=1e-4, atol=1e-5)


def test_ssd_kernel_vs_recurrent():
    rng = np.random.RandomState(5)
    x, dt, A, B, C = _ssd_inputs(rng, 2, 96, 3, 16, 24)
    gt = ref.ref_ssd_recurrent(x, dt, A, B, C)
    kn = ops.ssd_scan(x, dt, A, B, C, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(kn), np.asarray(gt),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([17, 64, 100]),
       st.sampled_from([16, 32]))
def test_ssd_kernel_shape_sweep(Bt, S, chunk):
    rng = np.random.RandomState(Bt * 31 + S)
    x, dt, A, B, C = _ssd_inputs(rng, Bt, S, 2, 8, 16)
    gt = ref.ref_ssd_recurrent(x, dt, A, B, C)
    kn = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(kn), np.asarray(gt),
                               rtol=1e-4, atol=1e-5)


def test_ssd_state_handoff():
    """Chunked-with-state == recurrent continuation (prefill -> decode)."""
    rng = np.random.RandomState(6)
    x, dt, A, B, C = _ssd_inputs(rng, 1, 33, 2, 8, 16)
    y, h = ref.ref_ssd(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32],
                       chunk=16, return_state=True)
    h2, y2 = ref.ref_ssd_decode_step(
        h, x[:, 32].astype(jnp.float32), dt[:, 32], A,
        B[:, 32, 0], C[:, 32, 0])
    gt = ref.ref_ssd_recurrent(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(gt[:, 32]),
                               rtol=1e-4, atol=1e-5)
