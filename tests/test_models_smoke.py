"""Per-architecture smoke tests (deliverable f): reduced same-family
configs, one forward + one train step on CPU, asserting shapes + no NaNs;
plus prefill/decode consistency and param/spec structure invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import frontends, registry
from repro.models import lm as LM
from repro.models import encdec as ED
from repro.models.common import XLA, assert_same_structure, count_params
from repro.train import loop as TL

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24, key=KEY, with_labels=False):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.frontend == "vision":
        batch["tokens"] = tok[:, :S - cfg.frontend_tokens]
        batch["prefix_embeds"] = frontends.fake_frontend(key, cfg, B, S,
                                                         jnp.float32)
    if cfg.frontend == "audio":
        batch["src_embeds"] = frontends.fake_frontend(key, cfg, B, S,
                                                      jnp.float32)
    if with_labels:
        batch["labels"] = jax.random.randint(
            jax.random.PRNGKey(7), batch["tokens"].shape, 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward(arch):
    cfg = configs.get_smoke(arch)
    model = registry.build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.forward_train(params, batch, XLA)
    S_out = 24 if cfg.frontend != "vision" else 24
    assert logits.shape == (2, S_out, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), remat="none")
    model = registry.build(cfg)
    state = TL.init_train_state(model, KEY)
    step = TL.make_train_step(model, TL.TrainConfig(), XLA)
    batch = _batch(cfg, with_labels=True)
    state2, m = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    assert int(state2["step"]) == 1
    # params actually changed (bitwise: warmup lr steps are tiny)
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not bool((d0 == d1).all())


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_spec_structures_match(arch):
    cfg = configs.get_smoke(arch)
    model = registry.build(cfg)
    params = jax.eval_shape(model.init, KEY)
    assert_same_structure(params, model.specs())


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    model = registry.build(cfg)
    params = model.init(KEY)
    B, S = 2, 17
    tok = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    full = {"tokens": tok}
    pref = {"tokens": tok[:, :S]}
    se = pe = None
    if cfg.frontend == "vision":
        pe = frontends.fake_frontend(KEY, cfg, B, S, jnp.float32)
        full["prefix_embeds"] = pref["prefix_embeds"] = pe
    if cfg.frontend == "audio":
        se = frontends.fake_frontend(KEY, cfg, B, S, jnp.float32)
        full["src_embeds"] = pref["src_embeds"] = se
    logits_full, _ = model.forward_train(params, full, XLA)
    extra = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    lp, cache = model.prefill(params, pref, XLA, cache_len=S + extra + 1)
    ld, _ = model.decode(params, {"tokens": tok[:, S:S + 1]}, cache, XLA)
    scale = float(jnp.abs(logits_full).max()) + 1e-6
    assert float(jnp.abs(lp - logits_full[:, -2]).max()) / scale < 1e-4
    assert float(jnp.abs(ld - logits_full[:, -1]).max()) / scale < 1e-4


def test_full_configs_match_assignment():
    """The exact assigned numbers (deliverable f spot checks)."""
    c = configs.get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (56, 6144, 48, 8)
    assert c.moe.num_experts == 8 and c.moe.top_k == 2
    c = configs.get_config("moonshot-v1-16b-a3b")
    assert c.moe.num_experts == 64 and c.moe.top_k == 6 and c.vocab == 163840
    c = configs.get_config("mamba2-780m")
    assert c.ssm.d_state == 128 and c.n_layers == 48 and c.d_model == 1536
    c = configs.get_config("zamba2-7b")
    assert c.n_layers == 81 and c.ssm.d_state == 64 and c.shared_attn_every
    c = configs.get_config("glm4-9b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.d_ff) == (40, 4096, 2, 13696)
    c = configs.get_config("gemma3-1b")
    assert c.attn.local_ratio == 5 and c.vocab == 262144
    c = configs.get_config("olmo-1b")
    assert not c.parametric_norm and c.vocab == 50304
    c = configs.get_config("smollm-360m")
    assert (c.n_heads, c.n_kv_heads, c.d_model) == (15, 5, 960)
    c = configs.get_config("seamless-m4t-large-v2")
    assert c.n_encoder_layers == 24 and c.vocab == 256206
    c = configs.get_config("internvl2-2b")
    assert c.frontend == "vision" and c.vocab == 92553


def test_head_padding_is_exact():
    """Zero-padded dead heads: identical logits, zero dead grads."""
    import numpy as np
    cfg0 = dataclasses.replace(configs.get_smoke("smollm-360m"),
                               dtype="float32", head_pad_multiple=0)
    cfg1 = dataclasses.replace(cfg0, head_pad_multiple=4)
    m0, m1 = registry.build(cfg0), registry.build(cfg1)
    p0, p1 = m0.init(KEY), m1.init(KEY)

    def pad_like(a, b):
        out = np.zeros(b.shape, np.float32)
        out[tuple(slice(0, s) for s in a.shape)] = np.asarray(a)
        return jnp.asarray(out)

    p1["blocks"]["attn"] = {k: pad_like(p0["blocks"]["attn"][k],
                                        p1["blocks"]["attn"][k])
                            for k in p1["blocks"]["attn"]}
    for k in ("embed", "final_norm"):
        p1[k] = p0[k]
    for k in ("ln1", "ln2", "mlp"):
        p1["blocks"][k] = p0["blocks"][k]
    tok = jax.random.randint(KEY, (2, 19), 0, cfg0.vocab)
    l0, _ = m0.forward_train(p0, {"tokens": tok}, XLA)
    l1, _ = m1.forward_train(p1, {"tokens": tok}, XLA)
    assert float(jnp.abs(l0 - l1).max()) == 0.0
    g = jax.grad(lambda p: (m1.forward_train(p, {"tokens": tok}, XLA)[0]
                            ** 2).sum())(p1)
    hd = cfg1.head_dim_
    assert float(jnp.abs(
        g["blocks"]["attn"]["wq"][:, :, cfg0.n_heads * hd:]).max()) == 0.0


def test_param_counts_sane():
    """Analytic count ~ actual count (MODEL_FLOPS denominator)."""
    for arch in ("olmo-1b", "glm4-9b", "mixtral-8x22b"):
        cfg = configs.get_config(arch)
        model = registry.build(cfg)
        actual = count_params(jax.eval_shape(model.init, KEY))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, \
            (arch, actual, analytic)
