"""repro.obs: registry semantics, histogram accuracy, spans, the Router
shape log / decision memo, BENCH export, and the kill switch."""
import json

import numpy as np
import pytest

from repro import api, obs
from repro.api import Policy
from repro.tune import classes, profile as profile_mod
from repro.tune.profile import DeviceProfile, ProfileEntry


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.set_enabled(True)
    obs.reset()
    yield
    obs.set_enabled(True)
    obs.reset()


# -- registry ---------------------------------------------------------------

def test_registry_returns_same_object_per_name_and_labels():
    c1 = obs.counter("t.events", op="gemm")
    c1.inc()
    c1.inc(2)
    assert obs.counter("t.events", op="gemm") is c1
    assert c1.value == 3
    # labels are part of the identity, order-insensitively
    assert obs.counter("t.events", op="matmul") is not c1
    assert obs.counter("t.x", a=1, b=2) is obs.counter("t.x", b=2, a=1)


def test_registry_kind_mismatch_raises():
    obs.counter("t.kind")
    with pytest.raises(TypeError):
        obs.gauge("t.kind")


def test_gauge_last_write_wins():
    g = obs.gauge("t.g")
    g.set(1.5)
    g.set(-2)
    assert g.value == -2.0


def test_registry_get_and_collect():
    assert obs.REGISTRY.get("t.absent") is None
    obs.counter("t.a").inc()
    obs.counter("u.b").inc()
    assert list(obs.REGISTRY.collect("t.")) == ["t.a"]
    snap = obs.REGISTRY.snapshot()
    assert snap["t.a"] == {"type": "counter", "value": 1}


# -- histogram --------------------------------------------------------------

def test_histogram_percentiles_track_numpy():
    """Log buckets promise <= sqrt(BASE)-1 ~ 4.4% relative error; check
    against exact numpy percentiles on a latency-shaped distribution."""
    rng = np.random.RandomState(42)
    samples = rng.lognormal(mean=5.0, sigma=1.2, size=2000)
    h = obs.histogram("t.lat_us")
    for s in samples:
        h.record(float(s))
    assert h.count == 2000
    np.testing.assert_allclose(h.mean, samples.mean(), rtol=1e-12)
    for q in (50, 95, 99):
        exact = np.percentile(samples, q)
        assert abs(h.percentile(q) - exact) / exact < 0.06, q
    # extremes are exact, and percentiles clamp inside them
    assert h.vmin == samples.min() and h.vmax == samples.max()
    assert h.percentile(100) <= samples.max()


def test_histogram_zero_and_negative_bucket():
    h = obs.histogram("t.z")
    h.record(0.0)
    h.record(-3.0)
    h.record(10.0)
    assert h.count == 3 and h.zeros == 2
    assert h.percentile(50) == 0.0       # rank 2 of 3 is still a zero
    assert abs(h.p99 - 10.0) / 10.0 < 0.045   # bucket midpoint resolution


def test_histogram_empty():
    h = obs.histogram("t.empty")
    assert h.count == 0 and h.mean == 0.0 and h.p50 == 0.0
    assert h.to_json()["min"] == 0.0


# -- spans ------------------------------------------------------------------

def test_span_nesting_builds_dotted_paths():
    with obs.span("outer"):
        with obs.span("inner"):
            pass
        with obs.span("inner"):
            pass
    with obs.span("outer"):
        pass
    outer = obs.REGISTRY.get("span.outer_us")
    inner = obs.REGISTRY.get("span.outer.inner_us")
    assert outer.count == 2
    assert inner.count == 2
    assert obs.REGISTRY.get("span.inner_us") is None


def test_span_records_elapsed_time():
    import time
    with obs.span("t.sleep"):
        time.sleep(0.01)
    h = obs.REGISTRY.get("span.t.sleep_us")
    assert h.count == 1 and h.vmin >= 9e3


# -- the Router shape log / decision memo -----------------------------------

def _route_batch(pol):
    r = api.Router(pol)
    for _ in range(3):
        r.route("matmul", (2, 8, 16, 32), "S", "NN")
    r.route("gemm", (45, 77, 33), "S", "NN")
    r.route("gemm", (45, 77, 33), "S", "NN")
    r.route("batched_gemm", (4, 16, 32, 64), "S", "NN")
    return r


def test_route_log_shape_counts():
    """The acceptance query: counts per (op, dtype, size-class)."""
    _route_batch(Policy(backend="auto"))
    counts = obs.ROUTES.shape_counts()
    assert sum(counts.values()) == 6
    b = classes.bucket_index
    assert counts[("matmul", "S", f"{b(16)}-{b(32)}-{b(16)}")] == 3
    assert counts[("gemm", "S", f"{b(45)}-{b(77)}-{b(33)}")] == 2
    assert counts[("batched_gemm", "S", f"{b(16)}-{b(64)}-{b(32)}")] == 1
    # full-label histogram carries the decision downstream tuning needs
    for (_op, _dt, _tr, _cls, _pallas, source, _blocks), n \
            in obs.ROUTES.histogram().items():
        assert source in ("forced", "profile", "analytical") and n >= 1


def test_route_memo_returns_cached_decision():
    pol = Policy(backend="auto")
    r = api.Router(pol)
    d1 = r.route("gemm", (45, 77, 33), "S", "NN")
    d2 = r.route("gemm", (45, 77, 33), "S", "NN")
    assert d2 is d1                      # memo hit, not a recompute
    # a different Policy object (even equal) must not alias the memo
    d3 = api.Router(Policy(backend="auto")).route(
        "gemm", (45, 77, 33), "S", "NN")
    assert d3 is not d1 and d3 == d1


def test_route_memo_invalidated_by_profile_change(tmp_path, monkeypatch):
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    profile_mod.clear_active_profile()
    pol = Policy(backend="tuned")
    r = api.Router(pol)
    d1 = r.route("gemm", (45, 45, 45), "S", "NN")
    assert d1.source == "analytical"     # no profile yet
    prof = DeviceProfile(profile_mod.current_device_kind())
    from repro.tune.timer import Measurement
    m = lambda us: Measurement(us, us, us, 3)  # noqa: E731
    prof.record(classes.size_class(45, 45, 45, "S", "NN"),
                ProfileEntry(None, m(100.0), m(1.0)))
    profile_mod.set_active_profile(prof)     # bumps ROUTES.gen
    d2 = r.route("gemm", (45, 45, 45), "S", "NN")
    assert d2.source == "profile" and not d2.use_pallas
    profile_mod.clear_active_profile()
    d3 = r.route("gemm", (45, 45, 45), "S", "NN")
    assert d3.source == "analytical"


def test_route_log_compaction_preserves_counts():
    rl = obs.ROUTES
    old_cap = rl.CAP
    rl.CAP = 4
    try:
        r = api.Router(Policy(backend="auto"))
        for m in range(8, 20):           # 12 distinct shapes > CAP
            r.route("gemm", (m, m, m), "S", "NN")
        assert rl.total == 12            # nothing lost across compactions
        assert len(rl.hits) <= 4
    finally:
        rl.CAP = old_cap


# -- BENCH export -----------------------------------------------------------

def test_export_load_diff_roundtrip(tmp_path):
    obs.counter("t.reqs").inc(10)
    obs.histogram("t.lat_us").record(100.0)
    _route_batch(Policy(backend="auto"))
    p1 = obs.export_bench("t1", {"note": "a"}, root=tmp_path)
    assert p1.name == "BENCH_t1.json"
    doc = obs.load_bench(p1)
    assert doc["schema"] == obs.BENCH_SCHEMA_VERSION
    assert doc["meta"] == {"note": "a"}
    assert doc["metrics"]["t.reqs"]["value"] == 10
    assert sum(r["count"] for r in doc["router"]) == 6
    # second run with more traffic diffs cleanly
    obs.counter("t.reqs").inc(10)
    p2 = obs.export_bench("t2", root=tmp_path)
    rows = {r[0]: r for r in obs.diff_bench(doc, obs.load_bench(p2))}
    _, old, new, pct = rows["t.reqs"]
    assert (old, new) == (10.0, 20.0) and pct == 100.0


def test_load_bench_rejects_wrong_schema(tmp_path):
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text(json.dumps({"bench": "bad", "schema": 999}))
    with pytest.raises(ValueError):
        obs.load_bench(bad)


# -- kill switch ------------------------------------------------------------

def test_env_parse_only_explicit_off_disables():
    for off in ("0", "false", "OFF", " no "):
        assert not obs._env_enabled(off)
    for on in (None, "", "1", "true", "yes", "anything"):
        assert obs._env_enabled(on)


def test_disabled_is_noop_everywhere():
    obs.set_enabled(False)
    c = obs.counter("t.dead")
    c.inc(5)
    assert c.value == 0                  # shared null object
    obs.gauge("t.dead_g").set(3)
    obs.histogram("t.dead_h").record(1.0)
    with obs.span("t.dead_span"):
        pass
    _route_batch(Policy(backend="auto"))
    assert obs.ROUTES.total == 0
    obs.set_enabled(True)
    assert obs.REGISTRY.snapshot() == {} # nothing leaked into the registry
    assert obs.REGISTRY.get("span.t.dead_span_us") is None


def test_disabled_routing_still_correct():
    obs.set_enabled(False)
    d = api.Router(Policy(backend="auto")).route(
        "gemm", (45, 77, 33), "S", "NN")
    assert d.source in ("forced", "analytical")
    assert isinstance(d.use_pallas, bool)
