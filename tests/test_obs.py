"""repro.obs: registry semantics, histogram accuracy, spans, the Router
shape log / decision memo, BENCH export, and the kill switch."""
import json

import numpy as np
import pytest

from repro import api, obs
from repro.api import Policy
from repro.tune import classes, profile as profile_mod
from repro.tune.profile import DeviceProfile, ProfileEntry


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.set_enabled(True)
    obs.reset()
    yield
    obs.set_enabled(True)
    obs.reset()


# -- registry ---------------------------------------------------------------

def test_registry_returns_same_object_per_name_and_labels():
    c1 = obs.counter("t.events", op="gemm")
    c1.inc()
    c1.inc(2)
    assert obs.counter("t.events", op="gemm") is c1
    assert c1.value == 3
    # labels are part of the identity, order-insensitively
    assert obs.counter("t.events", op="matmul") is not c1
    assert obs.counter("t.x", a=1, b=2) is obs.counter("t.x", b=2, a=1)


def test_registry_kind_mismatch_raises():
    obs.counter("t.kind")
    with pytest.raises(TypeError):
        obs.gauge("t.kind")


def test_gauge_last_write_wins():
    g = obs.gauge("t.g")
    g.set(1.5)
    g.set(-2)
    assert g.value == -2.0


def test_registry_get_and_collect():
    assert obs.REGISTRY.get("t.absent") is None
    obs.counter("t.a").inc()
    obs.counter("u.b").inc()
    assert list(obs.REGISTRY.collect("t.")) == ["t.a"]
    snap = obs.REGISTRY.snapshot()
    assert snap["t.a"] == {"type": "counter", "value": 1}


# -- histogram --------------------------------------------------------------

def test_histogram_percentiles_track_numpy():
    """Log buckets promise <= sqrt(BASE)-1 ~ 4.4% relative error; check
    against exact numpy percentiles on a latency-shaped distribution."""
    rng = np.random.RandomState(42)
    samples = rng.lognormal(mean=5.0, sigma=1.2, size=2000)
    h = obs.histogram("t.lat_us")
    for s in samples:
        h.record(float(s))
    assert h.count == 2000
    np.testing.assert_allclose(h.mean, samples.mean(), rtol=1e-12)
    for q in (50, 95, 99):
        exact = np.percentile(samples, q)
        assert abs(h.percentile(q) - exact) / exact < 0.06, q
    # extremes are exact, and percentiles clamp inside them
    assert h.vmin == samples.min() and h.vmax == samples.max()
    assert h.percentile(100) <= samples.max()


def test_histogram_zero_and_negative_bucket():
    h = obs.histogram("t.z")
    h.record(0.0)
    h.record(-3.0)
    h.record(10.0)
    assert h.count == 3 and h.zeros == 2
    assert h.percentile(50) == 0.0       # rank 2 of 3 is still a zero
    assert abs(h.p99 - 10.0) / 10.0 < 0.045   # bucket midpoint resolution


def test_histogram_empty():
    h = obs.histogram("t.empty")
    assert h.count == 0 and h.mean == 0.0 and h.p50 == 0.0
    assert h.to_json()["min"] == 0.0


def test_histogram_percentile_edges_are_exact():
    """q<=0 / q>=100 return the observed extremes — not the min/max
    *bucket* midpoints a ceil'd rank would land on."""
    h = obs.histogram("t.edge")
    for v in (3.0, 7.0, 250.0):
        h.record(v)
    assert h.percentile(0) == 3.0
    assert h.percentile(-5) == 3.0
    assert h.percentile(100) == 250.0
    assert h.percentile(150) == 250.0
    # a negative sample is the true minimum, not clamped to the 0 bucket
    h.record(-3.0)
    assert h.percentile(0) == -3.0


def test_histogram_percentile_all_zeros():
    h = obs.histogram("t.allz")
    for _ in range(5):
        h.record(0.0)
    assert h.zeros == 5
    for q in (0, 50, 100):
        assert h.percentile(q) == 0.0


# -- spans ------------------------------------------------------------------

def test_span_nesting_builds_dotted_paths():
    with obs.span("outer"):
        with obs.span("inner"):
            pass
        with obs.span("inner"):
            pass
    with obs.span("outer"):
        pass
    outer = obs.REGISTRY.get("span.outer_us")
    inner = obs.REGISTRY.get("span.outer.inner_us")
    assert outer.count == 2
    assert inner.count == 2
    assert obs.REGISTRY.get("span.inner_us") is None


def test_span_records_elapsed_time():
    import time
    with obs.span("t.sleep"):
        time.sleep(0.01)
    h = obs.REGISTRY.get("span.t.sleep_us")
    assert h.count == 1 and h.vmin >= 9e3


# -- the Router shape log / decision memo -----------------------------------

def _route_batch(pol):
    r = api.Router(pol)
    for _ in range(3):
        r.route("matmul", (2, 8, 16, 32), "S", "NN")
    r.route("gemm", (45, 77, 33), "S", "NN")
    r.route("gemm", (45, 77, 33), "S", "NN")
    r.route("batched_gemm", (4, 16, 32, 64), "S", "NN")
    return r


def test_route_log_shape_counts():
    """The acceptance query: counts per (op, dtype, size-class)."""
    _route_batch(Policy(backend="auto"))
    counts = obs.ROUTES.shape_counts()
    assert sum(counts.values()) == 6
    b = classes.bucket_index
    assert counts[("matmul", "S", f"{b(16)}-{b(32)}-{b(16)}")] == 3
    assert counts[("gemm", "S", f"{b(45)}-{b(77)}-{b(33)}")] == 2
    assert counts[("batched_gemm", "S", f"{b(16)}-{b(64)}-{b(32)}")] == 1
    # full-label histogram carries the decision downstream tuning needs
    for (_op, _dt, _tr, _cls, _pallas, source, _blocks), n \
            in obs.ROUTES.histogram().items():
        assert source in ("forced", "profile", "analytical") and n >= 1


def test_route_memo_returns_cached_decision():
    pol = Policy(backend="auto")
    r = api.Router(pol)
    d1 = r.route("gemm", (45, 77, 33), "S", "NN")
    d2 = r.route("gemm", (45, 77, 33), "S", "NN")
    assert d2 is d1                      # memo hit, not a recompute
    # a different Policy object (even equal) must not alias the memo
    d3 = api.Router(Policy(backend="auto")).route(
        "gemm", (45, 77, 33), "S", "NN")
    assert d3 is not d1 and d3 == d1


def test_route_memo_invalidated_by_profile_change(tmp_path, monkeypatch):
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    profile_mod.clear_active_profile()
    pol = Policy(backend="tuned")
    r = api.Router(pol)
    d1 = r.route("gemm", (45, 45, 45), "S", "NN")
    assert d1.source == "analytical"     # no profile yet
    prof = DeviceProfile(profile_mod.current_device_kind())
    from repro.tune.timer import Measurement
    m = lambda us: Measurement(us, us, us, 3)  # noqa: E731
    prof.record(classes.size_class(45, 45, 45, "S", "NN"),
                ProfileEntry(None, m(100.0), m(1.0)))
    profile_mod.set_active_profile(prof)     # bumps ROUTES.gen
    d2 = r.route("gemm", (45, 45, 45), "S", "NN")
    assert d2.source == "profile" and not d2.use_pallas
    profile_mod.clear_active_profile()
    d3 = r.route("gemm", (45, 45, 45), "S", "NN")
    assert d3.source == "analytical"


def test_route_log_compaction_preserves_counts():
    rl = obs.ROUTES
    old_cap = rl.CAP
    rl.CAP = 4
    try:
        r = api.Router(Policy(backend="auto"))
        for m in range(8, 20):           # 12 distinct shapes > CAP
            r.route("gemm", (m, m, m), "S", "NN")
        assert rl.total == 12            # nothing lost across compactions
        assert len(rl.hits) <= 4
    finally:
        rl.CAP = old_cap


def test_route_log_threaded_note_and_readers():
    """Writers inserting distinct shapes (every route is a memo miss ->
    ``note`` under the lock, repeatedly crossing CAP and compacting)
    race concurrent ``histogram``/``shape_counts`` readers: no
     'dict changed size during iteration', and the exact total survives
    because every write path holds the lock."""
    import threading
    rl = obs.ROUTES
    old_cap = rl.CAP
    rl.CAP = 64
    n_threads, n_shapes = 4, 300
    errors, stop = [], threading.Event()

    def write(tid):
        try:
            r = api.Router(Policy(backend="auto"))
            for i in range(n_shapes):
                m = 8 + tid * n_shapes + i          # distinct across threads
                r.route("gemm", (m, m, m), "S", "NN")
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    def read():
        try:
            while not stop.is_set():
                rl.histogram()
                rl.shape_counts()
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    try:
        ts = [threading.Thread(target=write, args=(t,))
              for t in range(n_threads)]
        ts.append(threading.Thread(target=read))
        for t in ts:
            t.start()
        for t in ts[:-1]:
            t.join()
        stop.set()
        ts[-1].join()
    finally:
        rl.CAP = old_cap
    assert not errors
    assert rl.total == n_threads * n_shapes
    assert len(rl.hits) <= 64


# -- windowed shape observation ---------------------------------------------

def test_routes_windowed_rotation_and_decay():
    b = classes.bucket_index
    ka = ("gemm", "S", f"{b(45)}-{b(77)}-{b(33)}")
    kb = ("gemm", "S", f"{b(300)}-{b(300)}-{b(300)}")
    r = api.Router(Policy(backend="auto"))
    r.route("gemm", (45, 77, 33), "S", "NN")
    w = obs.ROUTES.windowed(4, bucket_s=1.0, now=100.0)
    assert w == [{ka: 1}]                # window opens; nothing closed yet
    r.route("gemm", (45, 77, 33), "S", "NN")
    r.route("gemm", (300, 300, 300), "S", "NN")
    w = obs.ROUTES.windowed(4, bucket_s=1.0, now=101.5)
    assert w == [{}, {ka: 2, kb: 1}]     # bucket closed; fresh one empty
    r.route("gemm", (300, 300, 300), "S", "NN")
    w = obs.ROUTES.windowed(4, bucket_s=1.0, now=102.0)
    assert w == [{kb: 1}, {ka: 2, kb: 1}]   # 0.5s < 1s: still filling
    # decay fold: open bucket weighted 1, previous bucket decay**1
    folded = obs.ROUTES.windowed(4, bucket_s=1.0, decay=0.5, now=102.0)
    assert folded == {kb: 1 + 0.5 * 1, ka: 0.5 * 2}
    # a traffic shift dominates the folded view within one bucket
    assert folded[kb] > folded[ka]


def test_routes_windowed_caps_and_validates():
    r = api.Router(Policy(backend="auto"))
    for i in range(4):
        r.route("gemm", (45, 77, 33), "S", "NN")
        obs.ROUTES.windowed(8, bucket_s=1.0, now=100.0 + i)
    w = obs.ROUTES.windowed(2, bucket_s=1.0, now=110.0)
    assert len(w) == 2                   # n_buckets bounds the view
    with pytest.raises(ValueError):
        obs.ROUTES.windowed(0)
    with pytest.raises(ValueError):
        obs.ROUTES.windowed(2, decay=1.5)
    obs.ROUTES.reset()
    assert obs.ROUTES.windowed(4, bucket_s=1.0, now=200.0) == [{}]


# -- BENCH export -----------------------------------------------------------

def test_export_load_diff_roundtrip(tmp_path):
    obs.counter("t.reqs").inc(10)
    obs.histogram("t.lat_us").record(100.0)
    _route_batch(Policy(backend="auto"))
    p1 = obs.export_bench("t1", {"note": "a"}, root=tmp_path)
    assert p1.name == "BENCH_t1.json"
    doc = obs.load_bench(p1)
    assert doc["schema"] == obs.BENCH_SCHEMA_VERSION
    assert doc["meta"] == {"note": "a"}
    assert doc["metrics"]["t.reqs"]["value"] == 10
    assert sum(r["count"] for r in doc["router"]) == 6
    # second run with more traffic diffs cleanly
    obs.counter("t.reqs").inc(10)
    p2 = obs.export_bench("t2", root=tmp_path)
    rows = {r[0]: r for r in obs.diff_bench(doc, obs.load_bench(p2))}
    _, old, new, pct = rows["t.reqs"]
    assert (old, new) == (10.0, 20.0) and pct == 100.0


def test_load_bench_rejects_wrong_schema(tmp_path):
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text(json.dumps({"bench": "bad", "schema": 999}))
    with pytest.raises(ValueError):
        obs.load_bench(bad)


# -- kill switch ------------------------------------------------------------

def test_env_parse_only_explicit_off_disables():
    for off in ("0", "false", "OFF", " no "):
        assert not obs._env_enabled(off)
    for on in (None, "", "1", "true", "yes", "anything"):
        assert obs._env_enabled(on)


def test_disabled_is_noop_everywhere():
    obs.set_enabled(False)
    c = obs.counter("t.dead")
    c.inc(5)
    assert c.value == 0                  # shared null object
    obs.gauge("t.dead_g").set(3)
    obs.histogram("t.dead_h").record(1.0)
    with obs.span("t.dead_span"):
        pass
    _route_batch(Policy(backend="auto"))
    assert obs.ROUTES.total == 0
    obs.set_enabled(True)
    assert obs.REGISTRY.snapshot() == {} # nothing leaked into the registry
    assert obs.REGISTRY.get("span.t.dead_span_us") is None


def test_disabled_routing_still_correct():
    obs.set_enabled(False)
    d = api.Router(Policy(backend="auto")).route(
        "gemm", (45, 77, 33), "S", "NN")
    assert d.source in ("forced", "analytical")
    assert isinstance(d.use_pallas, bool)


# -- the CLI ----------------------------------------------------------------

def _cli(capsys, *argv):
    from repro.obs.__main__ import main
    rc = main(list(argv))
    return rc, capsys.readouterr().out


def test_cli_report_prints_live_registry(capsys):
    obs.counter("t.cli").inc(3)
    rc, out = _cli(capsys, "report")
    assert rc == 0 and "repro.obs report" in out and "t.cli" in out


def test_cli_ls_and_show(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    obs.counter("t.reqs").inc(4)
    obs.export_bench("one", {"note": "x"}, root=tmp_path)
    for cmd in ("ls", "list"):
        rc, out = _cli(capsys, cmd)
        assert rc == 0 and "BENCH_one.json" in out and "t.reqs" in out
    rc, out = _cli(capsys, "show", str(tmp_path / "BENCH_one.json"))
    assert rc == 0 and "note=x" in out


def test_cli_ls_empty_dir_hints(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    rc, out = _cli(capsys, "ls")
    assert rc == 0 and "no BENCH_*.json" in out


def test_cli_diff_percent_change_rows(tmp_path, capsys):
    obs.counter("t.reqs").inc(10)
    p1 = obs.export_bench("old", root=tmp_path)
    obs.counter("t.reqs").inc(5)
    obs.counter("t.fresh").inc(1)        # one-sided key prints "-"
    p2 = obs.export_bench("new", root=tmp_path)
    rc, out = _cli(capsys, "diff", str(p1), str(p2))
    assert rc == 0
    row = next(ln for ln in out.splitlines() if ln.startswith("t.reqs"))
    assert "+50.0%" in row and "10" in row and "15" in row
    fresh = next(ln for ln in out.splitlines() if ln.startswith("t.fresh"))
    assert fresh.rstrip().endswith("-")


def test_cli_arity_errors_exit_nonzero():
    from repro.obs.__main__ import main
    for argv in (["show"], ["diff", "one.json"], ["show", "a", "b"]):
        with pytest.raises(SystemExit) as ei:
            main(argv)
        assert ei.value.code != 0
