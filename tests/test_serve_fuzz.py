"""Seeded differential fuzz: random Poisson arrival traces through the
paged engine vs the wave oracle at temperature 0.

Each case draws a workload trace — Poisson inter-arrival gaps measured
in engine steps, mixed prompt lengths, mixed budgets — replays it into
a :class:`PagedEngine` whose pool is sized to force occasional
preemption, and demands token-identity with the single-request wave
reference for EVERY registry family.  Seeded, so a failure is a repro,
not a flake.  The full matrix is marked ``slow``; CI runs a small
instance (one ssm case) via ``-k``.
"""
import time

import jax
import numpy as np
import pytest

from repro import api, configs, obs
from repro.core.kernelgen import KernelSig
from repro.models import registry
from repro.models.common import XLA
from repro.serve import ContinuousBatcher, PagedEngine, Request
from repro.tune import classes as tune_classes, profile as profile_mod
from repro.tune.online import OnlineTuner
from repro.tune.profile import DeviceProfile, ProfileEntry
from repro.tune.timer import Measurement

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)

# dense, MoE, VLM, ssm, hybrid
FUZZ_ARCHS = ("olmo-1b", "moonshot-v1-16b-a3b", "internvl2-2b",
              "mamba2-780m", "zamba2-7b")


@pytest.fixture(scope="module")
def get_model():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke(arch)
            model = registry.build(cfg)
            cache[arch] = (cfg, model, model.init(KEY))
        return cache[arch]

    return get


@pytest.mark.parametrize("seed", (0, 1), ids=("s0", "s1"))
@pytest.mark.parametrize("arch", FUZZ_ARCHS)
def test_fuzz_poisson_trace_matches_wave(get_model, arch, seed):
    cfg, model, params = get_model(arch)
    rng = np.random.RandomState(1000 * FUZZ_ARCHS.index(arch) + seed)
    n = 7
    prompts = [rng.randint(0, cfg.vocab,
                           int(rng.randint(2, 28))).astype(np.int32)
               for _ in range(n)]
    maxnew = [int(rng.randint(2, 10)) for _ in range(n)]
    arrivals = np.cumsum(rng.poisson(3, size=n))

    # oracle: strictly sequential single-request runs
    ref = {}
    b = ContinuousBatcher(model, params, XLA, slots=1, max_len=64, eos=-1)
    for rid in range(n):
        b.submit(Request(rid, prompts[rid], max_new=maxnew[rid]))
    ref = b.run()

    # pool of 7 usable blocks x 8 << 3 slots' worst case -> preemption
    # pressure; fits_ever still holds for every single request
    e = PagedEngine(model, params, XLA, slots=3, max_len=64, eos=-1,
                    block_size=8, chunk=8, num_blocks=8)
    t, nxt = 0, 0
    while nxt < n:
        while nxt < n and arrivals[nxt] <= t:
            e.submit(Request(nxt, prompts[nxt], max_new=maxnew[nxt]))
            nxt += 1
        e.step()
        t += 1
    assert e.run() == ref
    assert e.cache.blocks_in_use == 0
    assert e.state.bound == 0 and e.state.binds == e.state.releases


def _pref_profile(pallas_us, xla_us):
    """A profile with one measured entry for the 45^3 class, preferring
    whichever side was given the smaller timing."""
    m = lambda us: Measurement(us, us, us, 1)  # noqa: E731
    p = DeviceProfile(profile_mod.current_device_kind())
    p.record(tune_classes.size_class(45, 45, 45, "S", "NN"),
             ProfileEntry(KernelSig("S", "NN", 128, 128, 128),
                          m(pallas_us), m(xla_us), "online"))
    return p


def test_fuzz_online_swap_token_parity(get_model, tmp_path, monkeypatch):
    """PR-10 differential: live profile swaps mid-stream — from a real
    background OnlineTuner AND deterministic manual ``set_active_profile``
    calls between engine steps — must be temperature-0 token-identical
    to a swap-free run.  Routing lives at jit trace time, so a swap can
    flip what a NEW compilation picks but never the numerics of a
    compiled step: routing decisions may change, results may not (the
    decision flip is asserted too, so the test can't pass vacuously)."""
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    profile_mod.clear_active_profile()
    obs.reset()
    cfg, model, params = get_model("olmo-1b")
    rng = np.random.RandomState(42)
    n = 6
    prompts = [rng.randint(0, cfg.vocab,
                           int(rng.randint(2, 28))).astype(np.int32)
               for _ in range(n)]
    maxnew = [int(rng.randint(2, 10)) for _ in range(n)]
    arrivals = np.cumsum(rng.poisson(2, size=n))
    p1, p2 = _pref_profile(1.0, 9.0), _pref_profile(9.0, 1.0)

    def sweeper(targets, *, budget):
        # measured-entry double with the budgeted_sweep contract; keeps
        # the CI instance off the stopwatch while still driving real
        # merge + set_active_profile swaps from the tuner thread
        delta = DeviceProfile(profile_mod.current_device_kind())
        m = Measurement(1.0, 1.0, 1.0, 1)
        tuned = []
        for t in targets[: budget // 2]:
            e = ProfileEntry(KernelSig("S", "NN", 128, 128, 128), m,
                             Measurement(2.0, 2.0, 2.0, 1), "online")
            (delta.record_grouped if t.kind == "grouped"
             else delta.record)(t.sc, e)
            tuned.append(t)
        return delta, tuned, 2 * len(tuned)

    try:
        # reference: same trace, no tuner, no profile
        ref_e = PagedEngine(model, params, XLA, slots=3, max_len=64,
                            eos=-1, block_size=8, chunk=8, num_blocks=8)
        t, nxt = 0, 0
        while nxt < n:
            while nxt < n and arrivals[nxt] <= t:
                ref_e.submit(Request(nxt, prompts[nxt],
                                     max_new=maxnew[nxt]))
                nxt += 1
            ref_e.step()
            t += 1
        ref = ref_e.run()

        obs.reset()
        tuner = OnlineTuner(interval_s=0.02, budget=4, sweeper=sweeper)
        e = PagedEngine(model, params, XLA, slots=3, max_len=64, eos=-1,
                        block_size=8, chunk=8, num_blocks=8, tuner=tuner)
        assert tuner.start()
        t, nxt = 0, 0
        stopped_in_flight = False
        while nxt < n:
            while nxt < n and arrivals[nxt] <= t:
                e.submit(Request(nxt, prompts[nxt], max_new=maxnew[nxt]))
                nxt += 1
            if t == 2:
                profile_mod.set_active_profile(p1)
            elif t == 5:
                profile_mod.set_active_profile(p2)
            elif t == 7 and not stopped_in_flight:
                # shutdown with requests in flight must not deadlock —
                # join the thread, possibly mid-cycle, bounded wait
                time.sleep(0.05)            # let at least one cycle land
                assert tuner.stop(timeout=10.0)
                stopped_in_flight = True
            e.step()
            t += 1
        assert stopped_in_flight and not tuner.running
        out = e.run()      # engine restarts the tuner and stops it on drain
        assert not tuner.running

        assert out == ref                   # token identity, swaps and all
        assert e.cache.blocks_in_use == 0
        assert obs.counter("serve.engine_fallback").value == 0
        swaps = [ev for ev in obs.TRACE.snapshot()
                 if ev[1] == "PROFILE_SWAP"]
        assert len(swaps) >= 2              # the manual swaps at least
        assert tuner.cycles >= 1            # the background loop really ran

        # the non-vacuity half: tuned-mode routing DID change across the
        # same two profiles the stream survived
        pol = api.Policy(backend="tuned")
        profile_mod.set_active_profile(p1)
        d1 = api.route("gemm", (45, 45, 45), "S", "NN", policy=pol)
        profile_mod.set_active_profile(p2)
        d2 = api.route("gemm", (45, 45, 45), "S", "NN", policy=pol)
        assert d1.source == d2.source == "profile"
        assert d1.use_pallas and not d2.use_pallas
    finally:
        profile_mod.clear_active_profile()
        obs.reset()
