"""Seeded differential fuzz: random Poisson arrival traces through the
paged engine vs the wave oracle at temperature 0.

Each case draws a workload trace — Poisson inter-arrival gaps measured
in engine steps, mixed prompt lengths, mixed budgets — replays it into
a :class:`PagedEngine` whose pool is sized to force occasional
preemption, and demands token-identity with the single-request wave
reference for EVERY registry family.  Seeded, so a failure is a repro,
not a flake.  The full matrix is marked ``slow``; CI runs a small
instance (one ssm case) via ``-k``.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.models.common import XLA
from repro.serve import ContinuousBatcher, PagedEngine, Request

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)

# dense, MoE, VLM, ssm, hybrid
FUZZ_ARCHS = ("olmo-1b", "moonshot-v1-16b-a3b", "internvl2-2b",
              "mamba2-780m", "zamba2-7b")


@pytest.fixture(scope="module")
def get_model():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke(arch)
            model = registry.build(cfg)
            cache[arch] = (cfg, model, model.init(KEY))
        return cache[arch]

    return get


@pytest.mark.parametrize("seed", (0, 1), ids=("s0", "s1"))
@pytest.mark.parametrize("arch", FUZZ_ARCHS)
def test_fuzz_poisson_trace_matches_wave(get_model, arch, seed):
    cfg, model, params = get_model(arch)
    rng = np.random.RandomState(1000 * FUZZ_ARCHS.index(arch) + seed)
    n = 7
    prompts = [rng.randint(0, cfg.vocab,
                           int(rng.randint(2, 28))).astype(np.int32)
               for _ in range(n)]
    maxnew = [int(rng.randint(2, 10)) for _ in range(n)]
    arrivals = np.cumsum(rng.poisson(3, size=n))

    # oracle: strictly sequential single-request runs
    ref = {}
    b = ContinuousBatcher(model, params, XLA, slots=1, max_len=64, eos=-1)
    for rid in range(n):
        b.submit(Request(rid, prompts[rid], max_new=maxnew[rid]))
    ref = b.run()

    # pool of 7 usable blocks x 8 << 3 slots' worst case -> preemption
    # pressure; fits_ever still holds for every single request
    e = PagedEngine(model, params, XLA, slots=3, max_len=64, eos=-1,
                    block_size=8, chunk=8, num_blocks=8)
    t, nxt = 0, 0
    while nxt < n:
        while nxt < n and arrivals[nxt] <= t:
            e.submit(Request(nxt, prompts[nxt], max_new=maxnew[nxt]))
            nxt += 1
        e.step()
        t += 1
    assert e.run() == ref
    assert e.cache.blocks_in_use == 0
    assert e.state.bound == 0 and e.state.binds == e.state.releases
