"""repro.serve.paged + sched + PagedEngine: block allocator properties,
scheduler state machine, and end-to-end parity with the wave reference.

The parity oracle is the wave engine at ``slots=1``: the wave engine
left-pads mixed-length prompts within a wave (pad tokens shift
positions), so its multi-slot outputs are batch-composition dependent —
only the unbatched run is the exact per-request generation the paged
engine must reproduce at temperature 0.
"""
import random

import jax
import numpy as np
import pytest

from repro import configs, obs
from repro.models import registry
from repro.models.common import XLA
from repro.serve import (BlockAllocator, CacheMap, ContinuousBatcher,
                         OutOfBlocks, PagedEngine, Request, Seq,
                         SlotScheduler)
from repro.serve import sched

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# Block allocator properties (pure host, no model).
# --------------------------------------------------------------------------

def test_allocator_unique_ids_and_exhaustion():
    a = BlockAllocator(8)                       # 7 usable; block 0 is null
    got = [a.alloc() for _ in range(7)]
    assert len(set(got)) == 7 and 0 not in got
    assert a.available == 0
    with pytest.raises(OutOfBlocks):
        a.alloc()


def test_allocator_double_free_and_null_free_rejected():
    a = BlockAllocator(4)
    b = a.alloc()
    a.free([b])
    with pytest.raises(ValueError):
        a.free([b])
    with pytest.raises(ValueError):
        a.free([0])


def test_allocator_churn_no_leak_no_alias():
    """Random alloc/free interleaving: held ids stay disjoint from the
    free list and held + available always equals capacity (no leak)."""
    a = BlockAllocator(16)
    rng = random.Random(0)
    held = []
    for _ in range(500):
        if held and (rng.random() < 0.5 or a.available == 0):
            a.free([held.pop(rng.randrange(len(held)))])
        else:
            b = a.alloc()
            assert b not in held, "allocator aliased a live block"
            held.append(b)
        assert len(held) + a.available == a.capacity
    a.free(held)
    assert a.available == a.capacity


def test_cache_map_grow_release_row():
    c = CacheMap(num_blocks=9, block_size=4, max_seq_len=16)
    c.ensure(7, 3)
    assert len(c.row(7)) == 4 and c.blocks_in_use == 1
    c.ensure(7, 9)                              # grow to 3 blocks
    row = c.row(7)
    assert c.blocks_in_use == 3 and (row[3] == 0)   # null-padded tail
    assert len(set(row[:3])) == 3
    c.release(7)
    assert c.blocks_in_use == 0 and c.allocator.available == 8
    assert c.fits_ever(16) and not c.fits_ever(17)


# --------------------------------------------------------------------------
# Scheduler state machine (host-only; CacheMap is pure host state).
# --------------------------------------------------------------------------

def _mk_sched(slots=2, num_blocks=9, block_size=4, max_seq=16):
    return SlotScheduler(CacheMap(num_blocks, block_size, max_seq), slots)


def _seq(rid, plen=3, max_new=4):
    return Seq(Request(rid, np.zeros(plen, np.int32), max_new=max_new))


def test_scheduler_fifo_admission_and_midflight_refill():
    s = _mk_sched(slots=2)
    for rid in range(4):
        s.submit(_seq(rid))
    admitted = s.admit()
    assert [q.rid for q in admitted] == [0, 1]      # FIFO into free slots
    assert s.admit() == []                          # slots full, queue waits
    s.finish(s.live[0])                             # mid-flight departure
    assert [q.rid for q in s.admit()] == [2]        # next in line, same slot
    assert sorted(s.live) == [1, 2]


def test_scheduler_finish_frees_blocks_and_slot():
    s = _mk_sched(slots=1)
    s.submit(_seq(5))
    (q,) = s.admit()
    s.cache.ensure(5, 9)
    assert s.cache.blocks_in_use == 3
    s.finish(q)
    assert s.cache.blocks_in_use == 0
    assert q.state == sched.DONE and s.slots[0] is None


def test_scheduler_preempt_requeues_front_and_frees():
    s = _mk_sched(slots=2)
    s.submit(_seq(0))
    s.submit(_seq(1))
    a, b = s.admit()
    s.cache.ensure(b.rid, 5)
    b.out = [7, 8]                              # generated prefix survives
    assert s.preempt_victim(a) is b             # youngest admitted loses
    s.preempt(b)
    assert s.cache.blocks_in_use == 0
    assert b.state == sched.QUEUED and b.pos == 0 and b.preemptions == 1
    assert b.out == [7, 8] and b.target[-2:] == [7, 8]
    s.submit(_seq(2))
    assert [q.rid for q in s.admit()] == [1]    # front of queue, before 2


def test_scheduler_rejects_never_fitting_request():
    s = _mk_sched(slots=1, num_blocks=3, block_size=4, max_seq=8)
    with pytest.raises(ValueError):
        s.submit(_seq(0, plen=6, max_new=8))    # 14 > 8-token pool


# --------------------------------------------------------------------------
# End-to-end parity with the wave reference, over EVERY registry family.
# --------------------------------------------------------------------------

# one smoke arch per decoder-only family: dense, MoE, VLM, ssm, hybrid —
# the parity suite runs each so a family can't silently lose its paged
# path again (the pre-PR regression: ssm/hybrid fell back to the wave)
PARITY_ARCHS = ("olmo-1b", "moonshot-v1-16b-a3b", "internvl2-2b",
                "mamba2-780m", "zamba2-7b")


@pytest.fixture(scope="module")
def get_model():
    """Module-cached (cfg, model, params) per arch, shared across the
    parametrized parity tests so each smoke model inits once."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke(arch)
            model = registry.build(cfg)
            cache[arch] = (cfg, model, model.init(KEY))
        return cache[arch]

    return get


@pytest.fixture(scope="module")
def smoke(get_model):
    return get_model("olmo-1b")


def _wave_ref(model, params, prompts, maxnew, eos=-1):
    """Unbatched wave-engine generations (the exact per-request oracle).
    One batcher at slots=1 runs the queue strictly sequentially, so its
    outputs are the per-request generations free of the wave engine's
    left-pad batch-composition effects."""
    b = ContinuousBatcher(model, params, XLA, slots=1, max_len=64, eos=eos)
    for rid, (p, mn) in enumerate(zip(prompts, maxnew)):
        b.submit(Request(rid, p, max_new=mn))
    return b.run()


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_parity_mixed_lengths_mid_decode_admission(get_model, arch):
    """Token-identical to the wave engine at temperature 0 across mixed
    prompt lengths / budgets, with half the requests admitted mid-decode
    of the others."""
    cfg, model, params = get_model(arch)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 3, 17, 2)]
    maxnew = [6, 5, 6, 3, 8]
    ref = _wave_ref(model, params, prompts, maxnew)

    e = PagedEngine(model, params, XLA, slots=2, max_len=64, eos=-1,
                    block_size=8, chunk=8)
    for rid in range(2):
        e.submit(Request(rid, prompts[rid], max_new=maxnew[rid]))
    for _ in range(4):                          # both slots mid-decode
        e.step()
    for rid in range(2, 5):                     # admitted mid-flight
        e.submit(Request(rid, prompts[rid], max_new=maxnew[rid]))
    assert e.run() == ref
    assert e.cache.blocks_in_use == 0           # every eviction freed


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_parity_under_preemption(get_model, arch):
    """A pool too small for both decoders forces preemption; recompute
    resume keeps the continuation token-identical — for the recurrent
    families this is the carry-rebuild path (prompt rows re-prefill with
    chunk numerics, replayed generated rows with decode numerics)."""
    cfg, model, params = get_model(arch)
    obs.reset()
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab, 7).astype(np.int32)
               for _ in range(2)]
    ref = _wave_ref(model, params, prompts, [10, 10])

    # capacity 3 blocks x 8 = 24 tokens; each request needs 2 blocks by
    # mid-decode, so demand hits 4 > 3 and the younger request cycles
    # through preempt -> re-queue -> recompute.
    e = PagedEngine(model, params, XLA, slots=2, max_len=24, eos=-1,
                    block_size=8, chunk=8, num_blocks=4)
    for rid, p in enumerate(prompts):
        e.submit(Request(rid, p, max_new=10))
    assert e.run() == ref
    assert obs.counter("serve.preemptions").value > 0
    assert e.cache.blocks_in_use == 0


def test_no_engine_fallback_for_registry_families():
    """Every decoder-only registry family builds with a paged serving
    path; the launcher's ``serve.engine_fallback`` counter (bumped only
    when a family misses the paged path) must stay 0."""
    obs.reset()
    for arch in PARITY_ARCHS:
        model = registry.build(configs.get_smoke(arch))
        assert model.paged_prefill is not None, arch
        assert model.paged_decode is not None, arch
        assert model.init_paged_state is not None, arch
    assert obs.counter("serve.engine_fallback").value == 0


def test_paged_parity_eos_eviction(smoke):
    """EOS truncation matches the wave engine and returns blocks."""
    cfg, model, params = smoke
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab, n).astype(np.int32)
               for n in (4, 6)]
    free_run = _wave_ref(model, params, prompts, [8, 8])
    eos = free_run[0][2]                        # a token that WILL appear
    ref = _wave_ref(model, params, prompts, [8, 8], eos=eos)
    assert any(len(v) < 8 for v in ref.values())    # eviction exercised

    e = PagedEngine(model, params, XLA, slots=2, max_len=64, eos=eos,
                    block_size=8, chunk=8)
    for rid, p in enumerate(prompts):
        e.submit(Request(rid, p, max_new=8))
    assert e.run() == ref
    assert e.cache.blocks_in_use == 0


def test_chunked_prefill_does_not_starve_decode(smoke):
    """A short decoding request keeps emitting tokens while a long
    prompt prefills chunk-by-chunk next to it — the short one finishes
    BEFORE the long one produces its first token."""
    cfg, model, params = smoke
    rng = np.random.RandomState(4)
    short = rng.randint(0, cfg.vocab, 3).astype(np.int32)
    long = rng.randint(0, cfg.vocab, 48).astype(np.int32)

    e = PagedEngine(model, params, XLA, slots=2, max_len=64, eos=-1,
                    block_size=8, chunk=8)
    e.submit(Request(0, short, max_new=4))
    while not e.scheduler.decoding():           # short is decoding...
        e.step()
    e.submit(Request(1, long, max_new=2))       # ...long starts prefilling
    while 0 not in e.done:
        e.step()
    q = e.scheduler.live.get(1)
    assert q is not None and q.state == sched.PREFILL and not q.out
    done = e.run()
    assert sorted(done) == [0, 1] and len(done[1]) == 2
