"""Per-slot recurrent state: SlotStateStore invariants, scheduler
lockstep, masked-decode carry isolation, and property-style
slot-isolation runs.

The hazard these tests pin down: the paged engine multiplexes MANY
requests through a FIXED set of slot-state rows (conv carries + SSM
state), so any bookkeeping slip — a row not zero-reset on reuse, an
inactive row advanced by a masked decode step, a preempted request
resuming on a stale carry — silently leaks one request's recurrence
into another's tokens.  Every end-to-end check therefore compares
against single-request reference runs (the wave oracle at ``slots=1``),
where no sharing exists by construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs
from repro.models import registry
from repro.models.common import XLA
from repro.serve import (CacheMap, ContinuousBatcher, PagedEngine, Request,
                         Seq, SlotScheduler, SlotStateStore)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def get_model():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke(arch)
            model = registry.build(cfg)
            cache[arch] = (cfg, model, model.init(KEY))
        return cache[arch]

    return get


def _wave_ref(model, params, prompts, maxnew, eos=-1):
    """Single-request reference runs (slots=1 processes sequentially)."""
    b = ContinuousBatcher(model, params, XLA, slots=1, max_len=64, eos=eos)
    for rid, (p, mn) in enumerate(zip(prompts, maxnew)):
        b.submit(Request(rid, p, max_new=mn))
    return b.run()


# --------------------------------------------------------------------------
# SlotStateStore invariants (pure host).
# --------------------------------------------------------------------------

def test_store_bind_release_invariants():
    with pytest.raises(ValueError):
        SlotStateStore(0)
    s = SlotStateStore(2)
    s.bind(0, 10)
    with pytest.raises(ValueError):
        s.bind(0, 11)                   # occupied slot
    with pytest.raises(ValueError):
        s.bind(1, 10)                   # request already bound
    with pytest.raises(ValueError):
        s.bind(2, 12)                   # slot out of range
    s.bind(1, 11)
    assert s.bound == 2
    assert s.owner(0) == 10 and s.slot_of(11) == 1
    with pytest.raises(ValueError):
        s.release(99)                   # releases nothing it never held
    assert s.release(10) == 0
    assert s.owner(0) is None and s.slot_of(10) is None
    s.bind(0, 12)                       # freed slot immediately rebindable
    assert s.binds == 3 and s.releases == 1


def test_scheduler_keeps_store_in_lockstep():
    """bind on admit, release on finish AND on preempt — always next to
    the block-table release, never drifting from it."""
    store = SlotStateStore(2)
    s = SlotScheduler(CacheMap(9, 4, 16), 2, store)
    for rid in range(3):
        s.submit(Seq(Request(rid, np.zeros(3, np.int32), max_new=4)))
    a, b = s.admit()
    assert store.owner(a.slot) == a.rid and store.owner(b.slot) == b.rid
    s.cache.ensure(b.rid, 5)
    bslot = b.slot
    s.preempt(b)
    assert store.owner(bslot) is None
    assert store.slot_of(b.rid) is None and s.cache.blocks_in_use == 0
    (c,) = s.admit()                    # preempted seq re-admits, rebinds
    assert c.rid == b.rid and store.slot_of(c.rid) == c.slot
    s.finish(a)
    assert store.slot_of(a.rid) is None
    assert store.binds == 3 and store.releases == 2
    assert store.bound == 1             # only the resumed seq remains


# --------------------------------------------------------------------------
# Masked decode: inactive slot rows are bitwise frozen (device).
# --------------------------------------------------------------------------

def test_masked_decode_freezes_inactive_carries(get_model):
    """A decode step with a slot masked inactive must leave that slot's
    conv/ssm rows bitwise unchanged and touch no pool block but the
    null sink (block 0) — zamba2 exercises both the recurrent rows and
    the shared-attention pool in one model."""
    cfg, model, params = get_model("zamba2-7b")
    slots = 3
    ps = model.init_paged_state(4, 8, slots)
    bt = jnp.zeros((slots, 4), jnp.int32)           # all-null tables
    pos = jnp.zeros((slots,), jnp.int32)
    toks = {"tokens": jnp.arange(1, slots + 1, dtype=jnp.int32)[:, None]}
    # one all-active step so the carries are non-zero (a frozen zero
    # row proves nothing)
    _, ps1 = model.paged_decode(params, toks, ps, bt, pos,
                                jnp.ones((slots,), bool), XLA)
    assert bool(jnp.any(ps1.conv != 0)) and bool(jnp.any(ps1.ssm != 0))

    # all-inactive step: different tokens, nothing may move
    _, ps2 = model.paged_decode(params, toks, ps1, bt, pos + 1,
                                jnp.zeros((slots,), bool), XLA)
    assert bool(jnp.all(ps2.conv == ps1.conv))
    assert bool(jnp.all(ps2.ssm == ps1.ssm))
    # writes landed in the null sink only
    assert bool(jnp.all(ps2.shared_k[:, 1:] == ps1.shared_k[:, 1:]))
    assert bool(jnp.all(ps2.shared_v[:, 1:] == ps1.shared_v[:, 1:]))

    # mixed step: only the active slot's rows advance
    act = jnp.array([False, True, False])
    _, ps3 = model.paged_decode(params, toks, ps2, bt, pos + 1, act, XLA)
    assert bool(jnp.all(ps3.conv[:, 0] == ps2.conv[:, 0]))
    assert bool(jnp.all(ps3.conv[:, 2] == ps2.conv[:, 2]))
    assert bool(jnp.all(ps3.ssm[:, 0] == ps2.ssm[:, 0]))
    assert bool(jnp.all(ps3.ssm[:, 2] == ps2.ssm[:, 2]))
    assert bool(jnp.any(ps3.ssm[:, 1] != ps2.ssm[:, 1]))


# --------------------------------------------------------------------------
# Property-style slot isolation (end-to-end vs single-request oracle).
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch,seed", [("mamba2-780m", 0),
                                       ("mamba2-780m", 1),
                                       ("zamba2-7b", 0)])
def test_slot_isolation_random_interleaving(get_model, arch, seed):
    """Random interleavings of admit / decode / budget-evict / preempt
    (pool sized to exhaust) over shared slots: every request's tokens
    must equal its single-request reference run — any cross-slot carry
    leak or stale-row reuse shows up as a token flip."""
    cfg, model, params = get_model(arch)
    rng = np.random.RandomState(seed)
    n = 6
    prompts = [rng.randint(0, cfg.vocab,
                           int(rng.randint(2, 20))).astype(np.int32)
               for _ in range(n)]
    maxnew = [int(rng.randint(2, 9)) for _ in range(n)]
    ref = _wave_ref(model, params, prompts, maxnew)

    e = PagedEngine(model, params, XLA, slots=2, max_len=64, eos=-1,
                    block_size=8, chunk=8, num_blocks=6)
    e.submit(Request(0, prompts[0], max_new=maxnew[0]))
    for rid in range(1, n):             # admissions land mid-flight
        for _ in range(int(rng.randint(0, 5))):
            e.step()
        e.submit(Request(rid, prompts[rid], max_new=maxnew[rid]))
    assert e.run() == ref
    assert e.state.bound == 0 and e.state.binds == e.state.releases
    assert e.cache.blocks_in_use == 0


def test_slot_isolation_eos_evict_and_reuse(get_model):
    """EOS-evicted slots hand their state row to the next request; the
    successor must start from a zero carry, not the evictee's."""
    cfg, model, params = get_model("mamba2-780m")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab, p).astype(np.int32)
               for p in (4, 6, 9, 5)]
    free = _wave_ref(model, params, prompts, [8, 8, 8, 8])
    eos = free[0][2]                    # a token that WILL appear
    ref = _wave_ref(model, params, prompts, [8, 8, 8, 8], eos=eos)
    assert any(len(v) < 8 for v in ref.values())    # eviction exercised

    e = PagedEngine(model, params, XLA, slots=2, max_len=64, eos=eos,
                    block_size=8, chunk=8)
    for rid, p in enumerate(prompts):
        e.submit(Request(rid, p, max_new=8))
    assert e.run() == ref
    assert e.state.bound == 0 and e.state.binds == 4


def test_exhaustion_resume_rebuilds_carry(get_model):
    """Block exhaustion preempts a decoding SSM request (carry row
    released with the blocks); recompute-resume re-prefills
    prompt+generated from a zero row and the continuation is
    token-identical to the never-preempted reference."""
    cfg, model, params = get_model("mamba2-780m")
    obs.reset()
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab, 7).astype(np.int32)
               for _ in range(2)]
    ref = _wave_ref(model, params, prompts, [10, 10])

    e = PagedEngine(model, params, XLA, slots=2, max_len=24, eos=-1,
                    block_size=8, chunk=8, num_blocks=4)
    for rid, p in enumerate(prompts):
        e.submit(Request(rid, p, max_new=10))
    assert e.run() == ref
    assert obs.counter("serve.preemptions").value > 0
    assert e.state.binds > 2            # at least one resume re-bound
    assert e.state.bound == 0 and e.cache.blocks_in_use == 0
