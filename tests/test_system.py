"""End-to-end behaviour tests for the paper's system.

Covers the full IAAT pipeline (install-time table -> run-time plan ->
kernel execution plan -> routing) and its integration into the model
stack (a pallas Policy routes model matmuls through the paper's path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro import api
from repro.core import kernelgen, plan as plan_mod
from repro.kernels import ref
from repro.models import registry
from repro.models.common import PALLAS_INTERPRET, XLA

KEY = jax.random.PRNGKey(0)


def test_install_then_plan_then_execute():
    """The paper's full two-stage flow on one problem."""
    n = kernelgen.install(letters=("S",), trans=("NN",), interpret=True,
                          max_per_family=10)
    assert n == 10
    p = plan_mod.build_plan(45, 77, 33, "S", "NN")
    assert p.num_kernel_calls >= 1
    assert p.memops() > 0
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(45, 33), jnp.float32)
    b = jnp.asarray(rng.randn(33, 77), jnp.float32)
    out = plan_mod.execute(p, a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b),
                               rtol=2e-5, atol=1e-4)


def test_plan_cache_repeated_calls():
    """'IAAT fits the situation where computes matrix multiplication with
    the same size repeatedly' — the plan is built once per signature."""
    plan_mod.build_plan.cache_clear()
    p1 = plan_mod.build_plan(33, 44, 55, "S", "NT")
    p2 = plan_mod.build_plan(33, 44, 55, "S", "NT")
    assert p1 is p2
    info = plan_mod.build_plan.cache_info()
    assert info.hits >= 1


def test_iaat_gemm_under_jit():
    rng = np.random.RandomState(1)
    a = jnp.asarray(rng.randn(24, 36), jnp.float32)
    b = jnp.asarray(rng.randn(36, 48), jnp.float32)

    @jax.jit
    def f(a, b):
        with api.using(backend="pallas", interpret=True):
            return api.gemm(a, b)

    np.testing.assert_allclose(np.asarray(f(a, b)),
                               np.asarray(a) @ np.asarray(b),
                               rtol=2e-5, atol=1e-4)


def test_iaat_gemm_differentiable():
    """The planned path is differentiable (needed for training use)."""
    rng = np.random.RandomState(2)
    a = jnp.asarray(rng.randn(16, 24), jnp.float32)
    b = jnp.asarray(rng.randn(24, 32), jnp.float32)

    def loss(a, b):
        with api.using(backend="pallas", interpret=True):
            return jnp.sum(api.gemm(a, b) ** 2)

    ga = jax.grad(loss)(a, b)
    ga_ref = jax.grad(lambda a, b: jnp.sum((a @ b) ** 2))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_ref),
                               rtol=2e-4, atol=2e-3)


def test_model_forward_through_iaat_backend():
    """A whole smoke model runs with every matmul routed through IAAT
    dispatch + pallas-interpret kernels, matching the XLA backend."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("olmo-1b"), dtype="float32")
    model = registry.build(cfg)
    params = model.init(KEY)
    tok = jax.random.randint(KEY, (1, 16), 0, cfg.vocab)
    l_xla, _ = model.forward_train(params, {"tokens": tok}, XLA)
    l_iaat, _ = model.forward_train(params, {"tokens": tok},
                                    PALLAS_INTERPRET)
    scale = float(jnp.abs(l_xla).max())
    assert float(jnp.abs(l_xla - l_iaat).max()) / scale < 5e-3


def test_moe_through_pallas_batched_gemm():
    """MoE expert compute through the batched small-GEMM kernel."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("moonshot-v1-16b-a3b"),
                              dtype="float32")
    model = registry.build(cfg)
    params = model.init(KEY)
    tok = jax.random.randint(KEY, (1, 16), 0, cfg.vocab)
    l_xla, _ = model.forward_train(params, {"tokens": tok}, XLA)
    be = PALLAS_INTERPRET.replace(backend="pallas", iaat=False)
    l_pl, _ = model.forward_train(params, {"tokens": tok}, be)
    scale = float(jnp.abs(l_xla).max())
    assert float(jnp.abs(l_xla - l_pl).max()) / scale < 5e-3


def test_dispatch_thresholds_route_correctly():
    with api.using(paper_thresholds=True):
        cfg = api.current_policy()
        assert cfg.threshold("NN") == 80
        assert cfg.threshold("TN") == 32
    cfg = api.current_policy()
    assert cfg.threshold("NN") == 80 * api.TPU_SCALE


def test_all_cells_enumerated():
    cells = configs.all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 34
    assert len(skipped) == 6
    assert all("full-attention" in c[3] or "500k" in c[3] for c in skipped)
