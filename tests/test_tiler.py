"""Unit + property tests for the run-time stage (input-aware tiling)."""
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip
from hypothesis import given, settings, strategies as st

from repro.core import cost, kernelgen, paper_table, vmem
from repro.core.tiler import TableView, tile, tile_armv8, tile_tpu


def test_paper_fig2_exact():
    """DP planner reproduces the paper's 72K+450 for 15x15 SGEMM_NN."""
    t = tile_armv8(15, 15, "S", "NN", "dp")
    assert t.coeff == paper_table.PAPER_FIG2_IAAT_COEFF == 72
    assert t.memops(15) == 72 * 15 + 2 * 15 * 15


def test_paper_fig2_blocks_match():
    t = tile_armv8(15, 15, "S", "NN", "dp")
    sizes = sorted((b.m, b.n) for b in t.blocks)
    assert sizes == [(3, 2), (3, 13), (12, 3), (12, 6), (12, 6)]


def test_greedy_matches_paper_alg2_shape():
    t = tile_armv8(15, 15, "S", "NN", "greedy")
    assert t.coeff >= 72          # greedy can't beat DP
    t.validate_cover()


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 96), st.integers(1, 96),
       st.sampled_from(["S", "D", "C", "Z"]),
       st.sampled_from(["NN", "NT", "TN", "TT"]))
def test_armv8_tiling_is_exact_cover(M, N, letter, trans):
    """Property: every tiling exactly partitions C with table kernels."""
    t = tile_armv8(M, N, letter, trans, "dp")
    t.validate_cover()
    sizes = set(paper_table.kernel_sizes(letter, trans))
    if trans in paper_table.MIRRORED:
        sizes = {(n, m) for m, n in sizes}
    for b in t.blocks:
        assert (b.m, b.n) in sizes, (b, letter, trans)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64))
def test_dp_never_worse_than_greedy(M, N):
    dp = tile_armv8(M, N, "S", "NN", "dp").coeff
    gr = tile_armv8(M, N, "S", "NN", "greedy").coeff
    assert dp <= gr


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 700), st.integers(1, 700),
       st.sampled_from(["S", "C"]), st.sampled_from(["NN", "NT", "TN", "TT"]))
def test_tpu_tiling_covers_aligned_extent(M, N, letter, trans):
    t = tile_tpu(M, N, letter, trans, "dp")
    t.validate_cover()
    table = kernelgen.kernel_table(letter, trans)
    dt = table[0].real_dtype
    assert t.M == vmem.align_m(M, dt)
    assert t.N == vmem.align_n(N, dt)
    legal = {(s.bm, s.bn) for s in table}
    for b in t.blocks:
        assert (b.m, b.n) in legal


def test_memops_objective_value():
    blocks = [(12, 6), (12, 6), (12, 3), (3, 13), (3, 2)]
    assert cost.memops_blocks(blocks, 15, 15, 15) == 72 * 15 + 450


def test_table_view_widths():
    tv = TableView.armv8("S", "NN")
    assert max(tv.widths_for(16)) == 4
    assert max(tv.widths_for(1)) == 13
    assert 16 in tv.heights()
