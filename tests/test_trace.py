"""repro.obs.trace: flight-recorder ring semantics, the per-request
reducer, the Perfetto/Chrome-trace export, file + CLI round-trips, and
a live paged-engine integration pass under forced preemption."""
import json
import threading

import jax
import numpy as np
import pytest

from repro import configs, obs
from repro.models import registry
from repro.models.common import XLA
from repro.obs import trace
from repro.serve import PagedEngine, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.set_enabled(True)
    obs.reset()
    yield
    obs.set_enabled(True)
    obs.reset()


# --------------------------------------------------------------------------
# EventLog ring semantics.
# --------------------------------------------------------------------------

def test_ring_drops_oldest_and_counts_drops():
    log = trace.EventLog(capacity=4)
    for i in range(10):
        log.emit("DECODE_TICK", arg=i)
    assert len(log) == 4
    assert log.n_total == 10 and log.dropped == 6
    # the ring keeps the most recent window, oldest-first
    assert [e[4] for e in log.snapshot()] == [6, 7, 8, 9]


def test_ring_reset_and_disable():
    log = trace.EventLog(capacity=8)
    log.emit("FINISH", rid=1, slot=0, arg=5)
    log.reset()
    assert len(log) == 0 and log.n_total == 0 and log.dropped == 0
    log.set_enabled(False)
    log.emit("FINISH", rid=1)
    assert len(log) == 0                 # disabled emit is a no-op
    log.set_enabled(True)
    log.emit("FINISH", rid=1)
    assert len(log) == 1


def test_ring_rejects_unknown_event_and_bad_capacity():
    log = trace.EventLog(capacity=4)
    with pytest.raises(ValueError, match="unknown trace event"):
        log.emit("NOT_AN_EVENT")
    with pytest.raises(ValueError):
        trace.EventLog(capacity=0)


def test_ring_capacity_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CAP", "7")
    assert trace.EventLog().capacity == 7


def test_ring_threaded_emits_never_corrupt():
    """Concurrent emitters + a reader snapshotting mid-stream: the ring
    never raises, snapshots are always well-formed, and the derived
    dropped count stays consistent with what survived."""
    log = trace.EventLog(capacity=256)
    n, nthreads = 2000, 4
    errors = []

    def work():
        try:
            for _ in range(n):
                log.emit("DECODE_TICK")
        except Exception as e:           # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=work) for _ in range(nthreads)]
    for t in threads:
        t.start()
    snaps = [log.snapshot() for _ in range(100)]  # concurrent reads
    for t in threads:
        t.join()
    assert not errors
    assert all(e[1] == "DECODE_TICK" for s in snaps for e in s)
    assert len(log) == 256
    assert log.dropped == log.n_total - 256
    assert log.n_total <= n * nthreads   # += under the GIL never overcounts


def test_trace_follows_obs_kill_switch():
    obs.set_enabled(False)
    assert not obs.TRACE.on
    obs.TRACE.emit("FINISH", rid=1)
    assert len(obs.TRACE) == 0
    obs.set_enabled(True)
    assert obs.TRACE.on


def test_trace_env_parse(monkeypatch):
    for off in ("0", "false", " OFF ", "no"):
        monkeypatch.setenv("REPRO_TRACE", off)
        assert not trace._trace_env_on()
    for on in ("", "1", "true", "anything"):
        monkeypatch.setenv("REPRO_TRACE", on)
        assert trace._trace_env_on()
    monkeypatch.delenv("REPRO_TRACE")
    assert trace._trace_env_on()


# --------------------------------------------------------------------------
# Per-request reducer.
# --------------------------------------------------------------------------

_T = 1e-3           # 1 ms between synthetic events


def _preempted_request():
    """rid 7: arrive, wait 2ms, prefill, preempt BEFORE the first token
    (2ms gap -> TTFT wait), resume, first token, preempt AFTER it (3ms
    gap -> decode stall), resume, finish."""
    return [
        (0 * _T, "REQ_ARRIVE", 7, -1, (10, 4), None),
        (2 * _T, "ADMIT", 7, 0, None, None),
        (3 * _T, "PREFILL_CHUNK", 7, 0, (0, 10), 500.0),
        (4 * _T, "PREEMPT", 7, 0, None, None),
        (6 * _T, "RESUME", 7, 1, None, None),
        (7 * _T, "FIRST_TOKEN", 7, 1, None, None),
        (8 * _T, "PREEMPT", 7, 1, None, None),
        (11 * _T, "RESUME", 7, 2, None, None),
        (12 * _T, "FINISH", 7, 2, 4, None),
    ]


def test_reducer_ttft_breakdown_and_decode_stall():
    r = trace.per_request(_preempted_request())[7]
    assert r["queue_wait_us"] == pytest.approx(2000, abs=0.1)
    assert r["ttft_us"] == pytest.approx(7000, abs=0.1)
    # wait = initial 2ms + the pre-first-token preemption gap of 2ms
    assert r["ttft_wait_us"] == pytest.approx(4000, abs=0.1)
    assert r["ttft_prefill_us"] == pytest.approx(3000, abs=0.1)
    assert r["ttft_wait_us"] + r["ttft_prefill_us"] == \
        pytest.approx(r["ttft_us"], abs=0.2)
    # the post-first-token gap (8ms -> 11ms) is decode stall, not TTFT
    assert r["decode_stall_us"] == pytest.approx(3000, abs=0.1)
    assert r["preemptions"] == 2 and r["prefill_chunks"] == 1
    assert r["finished"] and r["n_out"] == 4
    assert r["e2e_us"] == pytest.approx(12000, abs=0.1)


def test_reducer_tolerates_partial_trace():
    """A request whose REQ_ARRIVE fell off the ring anchors at its first
    surviving event instead of raising."""
    evs = [(1.0, "ADMIT", 3, 0, None, None),
           (2.0, "FIRST_TOKEN", 3, 0, None, None),
           (3.0, "FINISH", 3, 0, 2, None)]
    r = trace.per_request(evs)[3]
    assert r["queue_wait_us"] == 0.0
    assert r["finished"] and r["n_out"] == 2


def test_reducer_skips_batch_and_router_events():
    evs = [(0.0, "DECODE_TICK", -1, -1, (8, 2), None),
           (0.1, "ROUTE_MISS", -1, -1, ("gemm", "S", "NN", [4, 8, 8],
                                        "analytical"), None)]
    assert trace.per_request(evs) == {}


def test_observe_folds_reducer_into_registry():
    per = trace.per_request(_preempted_request())
    trace.observe(per)
    h = obs.REGISTRY.get("serve.trace.queue_wait_us")
    assert h is not None and h.count == 1
    assert obs.REGISTRY.get("serve.trace.preemptions").vmax == 2
    s = trace.summary(per)
    assert s["requests"] == 1 and s["finished"] == 1
    assert s["preemptions"] == 2
    assert s["ttft_wait_p50_us"] == pytest.approx(4000, abs=0.1)


# --------------------------------------------------------------------------
# Perfetto export.
# --------------------------------------------------------------------------

def _two_request_stream():
    return _preempted_request() + [
        (0.5 * _T, "REQ_ARRIVE", 8, -1, (4, 2), None),
        (4.5 * _T, "ADMIT", 8, 0, None, None),
        (5.0 * _T, "FIRST_TOKEN", 8, 0, None, None),
        (5.5 * _T, "FINISH", 8, 0, 2, None),
        (2.5 * _T, "ROUTE_MISS", -1, -1, ("gemm", "S", "NN", [4, 8, 8],
                                          "analytical"), None),
        (9 * _T, "EVICT", 7, -1, 2, None),
        (0.1 * _T, "PROFILE_SWAP", -1, -1, "cpu/interpret:3", None),
    ]


def test_perfetto_tracks_slices_and_flows():
    doc = trace.perfetto(_two_request_stream(), slots=3)
    te = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    tracks = {e["args"]["name"] for e in te
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"queue", "slot 0", "slot 1", "slot 2"} <= tracks
    procs = {e["args"]["name"] for e in te
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert procs == {"repro.serve", "repro.router"}

    slices = [e for e in te if e["ph"] == "X"]
    assert all(e["ts"] >= 0 and e["dur"] > 0 for e in slices)
    r7 = {e["name"] for e in slices if e.get("args", {}).get("rid") == 7}
    assert {"req 7 queued", "req 7 prefill", "req 7 decode",
            "req 7 queued (preempted)"} <= r7

    # the preemption gap is a visible slice on the queue track
    gap = [e for e in slices if e["name"] == "req 7 queued (preempted)"]
    assert len(gap) == 2                 # one per preemption
    assert all(e["tid"] == 0 for e in gap)
    assert sorted(round(e["dur"]) for e in gap) == [2000, 3000]

    # flow chains: per request one start, then continuations, then the
    # terminating step at FINISH
    for rid in (7, 8):
        fl = [e["ph"] for e in te if e["ph"] in ("s", "t", "f")
              and e.get("id") == rid]
        assert fl[0] == "s" and fl[-1] == "f" and "s" not in fl[1:]

    inst = {e["name"] for e in te if e["ph"] == "i"}
    assert {"preempt req 7", "evict req 7", "route_miss",
            "profile_swap"} <= inst


def test_perfetto_closes_unfinished_slices():
    evs = [(0.0, "REQ_ARRIVE", 1, -1, (4, 8), None),
           (0.001, "ADMIT", 1, 0, None, None)]  # never finishes
    doc = trace.perfetto(evs)
    open_names = [e["name"] for e in doc["traceEvents"] if e["ph"] == "X"]
    assert "req 1 queued" in open_names  # closed at the capture edge


def test_perfetto_empty_stream():
    assert trace.perfetto([]) == {"traceEvents": [],
                                  "displayTimeUnit": "ms"}


# --------------------------------------------------------------------------
# File + CLI round-trip.
# --------------------------------------------------------------------------

def test_write_trace_roundtrip(tmp_path):
    evs = _two_request_stream()
    p = trace.write_trace(tmp_path / "t.json", evs, slots=3)
    doc = json.loads(p.read_text())
    assert doc["reproTrace"]["schema"] == trace.TRACE_SCHEMA_VERSION
    assert len(doc["reproTrace"]["events"]) == len(evs)
    assert {r["rid"] for r in doc["otherData"]["per_request"]} == {7, 8}
    back = trace.load_events(p)
    # rebased + ns-rounded timestamps preserve every derived metric
    assert trace.per_request(back) == trace.per_request(evs)


def test_load_events_rejects_foreign_and_versioned_files(tmp_path):
    p = tmp_path / "x.json"
    p.write_text(json.dumps({"traceEvents": []}))
    with pytest.raises(ValueError, match="no reproTrace"):
        trace.load_events(p)
    p.write_text(json.dumps({"reproTrace": {"schema": 99, "events": []}}))
    with pytest.raises(ValueError, match="schema"):
        trace.load_events(p)


def test_cli_trace_reexport(tmp_path, capsys):
    from repro.obs.__main__ import main
    src = trace.write_trace(tmp_path / "in.json", _two_request_stream())
    assert main(["trace", str(src), str(tmp_path / "out.json")]) == 0
    out = capsys.readouterr().out
    assert "rid" in out and "wrote" in out
    assert trace.load_events(tmp_path / "out.json")


def test_cli_trace_live_ring(tmp_path, capsys):
    from repro.obs.__main__ import main
    obs.TRACE.emit("REQ_ARRIVE", rid=5, arg=(4, 2))
    obs.TRACE.emit("ADMIT", rid=5, slot=0)
    obs.TRACE.emit("FINISH", rid=5, slot=0, arg=2)
    assert main(["trace", str(tmp_path / "live.json")]) == 0
    assert "wrote" in capsys.readouterr().out
    assert len(trace.load_events(tmp_path / "live.json")) == 3


def test_cli_trace_wrong_arity():
    from repro.obs.__main__ import main
    with pytest.raises(SystemExit):
        main(["trace"])


# --------------------------------------------------------------------------
# Live engine integration: the trace of a real preemption-forcing run.
# --------------------------------------------------------------------------

def test_paged_engine_emits_full_lifecycle():
    cfg = configs.get_smoke("olmo-1b")
    model = registry.build(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab, 7).astype(np.int32)
               for _ in range(2)]
    # 3 usable blocks x 8 < peak demand -> the younger request preempts
    e = PagedEngine(model, params, XLA, slots=2, max_len=24, eos=-1,
                    block_size=8, chunk=8, num_blocks=4)
    for rid, p in enumerate(prompts):
        e.submit(Request(rid, p, max_new=10))
    done = e.run()
    assert len(done) == 2

    evs = obs.TRACE.snapshot()
    kinds = {e[1] for e in evs}
    assert {"REQ_ARRIVE", "ADMIT", "PREFILL_CHUNK", "FIRST_TOKEN",
            "PREEMPT", "RESUME", "FINISH", "EVICT"} <= kinds
    per = trace.per_request(evs)
    assert set(per) == {0, 1}
    assert all(r["finished"] and r["n_out"] == 10 for r in per.values())
    assert sum(r["preemptions"] for r in per.values()) > 0
    # every chunk event carries a measured duration
    assert all(e[5] > 0 for e in evs if e[1] == "PREFILL_CHUNK")
    # reducer totals agree with the engine's own preemption counter
    assert sum(r["preemptions"] for r in per.values()) == \
        obs.counter("serve.preemptions").value

    doc = trace.perfetto(evs, slots=2)
    slices = [x for x in doc["traceEvents"] if x["ph"] == "X"]
    preempted = [r for r, rec in per.items() if rec["preemptions"]]
    for rid in preempted:
        assert any(x["name"] == f"req {rid} queued (preempted)"
                   for x in slices)


# --------------------------------------------------------------------------
# Online-tuner events: TUNE_CYCLE track + swap/miss attribution.
# --------------------------------------------------------------------------

def test_perfetto_tune_cycle_renders_on_own_tuner_track():
    evs = [(0.0, "ROUTE_MISS", -1, -1,
            ("gemm", "S", "NN", [45, 45, 45], "analytical"), None),
           (0.010, "TUNE_CYCLE", -1, -1, (1, 2, 4, True), 2500.0),
           (0.020, "TUNE_CYCLE", -1, -1, (2, 0, 0, False), None)]
    doc = trace.perfetto(evs)
    te = doc["traceEvents"]
    tracks = {(e["pid"], e["tid"]): e["args"]["name"] for e in te
              if e["ph"] == "M" and e["name"] == "thread_name"}
    router_pid = next(e["pid"] for e in te if e["ph"] == "M"
                      and e["name"] == "process_name"
                      and e["args"]["name"] == "repro.router")
    assert tracks[(router_pid, 0)] == "route/profile"
    assert tracks[(router_pid, 1)] == "online tuner"

    # a cycle with a measured duration is a complete slice spanning
    # backwards from its end-of-cycle emit time, on the tuner's track
    cyc = [e for e in te if e["ph"] == "X" and e["name"] == "tune_cycle"]
    assert len(cyc) == 1
    assert cyc[0]["pid"] == router_pid and cyc[0]["tid"] == 1
    assert cyc[0]["dur"] == pytest.approx(2500.0)
    assert cyc[0]["ts"] == pytest.approx(10000.0 - 2500.0)
    assert cyc[0]["args"]["cycle"] == (1, 2, 4, True)
    # without a duration it degrades to an instant, same track
    inst = [e for e in te if e["ph"] == "i" and e["name"] == "tune_cycle"]
    assert len(inst) == 1 and inst[0]["tid"] == 1
    # the route instants stay off the tuner track
    miss = [e for e in te if e["ph"] == "i" and e["name"] == "route_miss"]
    assert miss and all(e["tid"] == 0 for e in miss)


def test_swap_to_miss_burst_attribution_survives_roundtrip(tmp_path,
                                                           monkeypatch):
    """The debugging story the trace exists for: a PROFILE_SWAP followed
    by the ROUTE_MISS burst it caused, with ordering and args intact
    after the write_trace/load_events roundtrip — generated by the real
    Router/profile machinery, not synthetic tuples."""
    from repro import api
    from repro.tune import profile as profile_mod
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    profile_mod.clear_active_profile()
    obs.TRACE.reset()
    r = api.Router(api.Policy(backend="auto"))
    dims = [(16, 16, 16), (32, 32, 32), (48, 48, 48)]
    for d in dims:
        r.route("gemm", d, "S", "NN")    # cold misses
        r.route("gemm", d, "S", "NN")    # memo hits: silent
    profile_mod.set_active_profile(None)  # the swap under test
    for d in dims:
        r.route("gemm", d, "S", "NN")    # recompute burst
    try:
        evs = obs.TRACE.snapshot()
        seq = [(e[1], e[4]) for e in evs
               if e[1] in ("ROUTE_MISS", "PROFILE_SWAP")]
        kinds = [k for k, _ in seq]
        # 3 cold misses, one swap, then exactly 3 re-route misses —
        # the memoized hot path emitted nothing
        assert kinds == ["ROUTE_MISS"] * 3 + ["PROFILE_SWAP"] \
            + ["ROUTE_MISS"] * 3
        swap_at = kinds.index("PROFILE_SWAP")
        burst = seq[swap_at + 1:]
        assert [tuple(a[3]) for _, a in burst] == dims

        back = trace.load_events(trace.write_trace(tmp_path / "t.json",
                                                   evs))
        seq2 = [(e[1], e[4]) for e in back
                if e[1] in ("ROUTE_MISS", "PROFILE_SWAP")]
        # args come back as lists after JSON; compare re-normalized
        norm = lambda s: [(k, json.loads(json.dumps(list(a)))
                           if isinstance(a, (tuple, list)) else a)
                          for k, a in s]  # noqa: E731
        assert norm(seq2) == norm(seq)
        # timestamps stay monotone through the rebase
        ts = [e[0] for e in back]
        assert ts == sorted(ts)
    finally:
        profile_mod.clear_active_profile()
