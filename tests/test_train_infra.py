"""Integration tests: optimizer, checkpoint/restore, fault tolerance,
data determinism, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.models.common import XLA
from repro.serve.engine import ContinuousBatcher, Request
from repro.train import checkpoint as ck
from repro.train import data as data_mod
from repro.train import fault
from repro.train import loop as TL
from repro.train import optimizer as opt

KEY = jax.random.PRNGKey(0)


def test_adamw_reduces_loss_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_opt_state(params)
    c = opt.OptConfig(peak_lr=0.2, warmup_steps=1, decay_steps=1000,
                      weight_decay=0.0)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = opt.adamw_update(params, g, state, step + i, c)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    st = opt.init_opt_state(params)
    g = {"w": jnp.full((4,), 1e6)}
    c = opt.OptConfig(clip_norm=1.0, warmup_steps=1)
    _, _, m = opt.adamw_update(params, g, st, jnp.zeros((), jnp.int32), c)
    assert float(m["grad_norm"]) > 1e5    # reported pre-clip


def test_schedule_warmup_then_decay():
    c = opt.OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(opt.schedule(jnp.asarray(s), c)) for s in (0, 9, 50, 99)]
    assert lrs[0] < lrs[1] <= 1.0
    assert lrs[2] > lrs[3] >= 0.1 * 0.99


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = configs.get_smoke("olmo-1b")
    model = registry.build(cfg)
    state = TL.init_train_state(model, KEY)
    cp = ck.Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        cp.save(s, state, extra={"data_step": s})
    assert cp.all_steps() == [2, 3]      # keep=2 GC'd step 1
    like = jax.eval_shape(lambda: TL.init_train_state(model, KEY))
    restored, extra = cp.restore(like)
    assert extra["data_step"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async(tmp_path):
    cp = ck.Checkpointer(str(tmp_path))
    state = {"a": jnp.arange(10)}
    cp.save(5, state, async_=True)
    cp.wait()
    restored, _ = cp.restore({"a": jnp.zeros(10, jnp.int32)})
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir is never treated as a checkpoint."""
    cp = ck.Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / "step_00000009.tmp")
    cp.save(3, {"a": jnp.ones(3)})
    assert cp.latest_step() == 3


def test_data_determinism_and_host_sharding():
    d = data_mod.SyntheticTokens(vocab=100, seq_len=16, global_batch=8,
                                 seed=3)
    b1 = d.batch(7)
    b2 = d.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(8)["tokens"], b1["tokens"])
    h0 = d.batch(7, host=0, num_hosts=2)
    h1 = d.batch(7, host=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_memmap_dataset(tmp_path):
    arr = np.arange(10_000, dtype=np.int32) % 777
    path = tmp_path / "toks.bin"
    arr.tofile(path)
    d = data_mod.MemmapTokens(str(path), seq_len=16, global_batch=4)
    b = d.batch(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_step_monitor_flags_stragglers():
    mon = fault.StepMonitor(z_thresh=2.0, warmup=3)
    import time
    for i in range(8):
        mon.start()
        time.sleep(0.001 if i != 6 else 0.08)
        st = mon.stop(i)
    assert any(s.straggler for s in mon.history)
    assert mon.summary()["stragglers"] >= 1


def test_run_with_restarts_retries():
    calls = []

    def train_once(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise fault.SimulatedFault("boom")
        return 42

    assert fault.run_with_restarts(train_once, max_restarts=3) == 42
    assert calls == [0, 1, 2]


def test_training_recovers_after_fault(tmp_path):
    """End-to-end: fault at step k resumes from checkpoint, identical
    loss trajectory (deterministic data + exact checkpoint restore)."""
    from repro.launch.train import build_args, run
    args = build_args([
        "--arch", "olmo-1b", "--smoke", "--steps", "8", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
        "--inject-fault-at", "6", "--log-every", "100"])
    out = run(args)
    assert out["final_step"] == 8
    args2 = build_args([
        "--arch", "olmo-1b", "--smoke", "--steps", "8", "--batch", "4",
        "--seq", "32", "--log-every", "100"])
    out2 = run(args2)
    assert abs(out["loss"] - out2["loss"]) < 1e-4


def test_continuous_batcher_serves_all():
    cfg = configs.get_smoke("olmo-1b")
    model = registry.build(cfg)
    params = model.init(KEY)
    b = ContinuousBatcher(model, params, XLA, slots=2, max_len=64)
    rng = np.random.RandomState(0)
    for rid in range(5):
        b.submit(Request(rid, rng.randint(0, cfg.vocab, 6).astype(np.int32),
                         max_new=4))
    done = b.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(1 <= len(v) <= 4 for v in done.values())


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written under one 'mesh' restores under another (here:
    default device placement) — layout is mesh-independent."""
    cfg = configs.get_smoke("glm4-9b")
    model = registry.build(cfg)
    state = TL.init_train_state(model, KEY)
    cp = ck.Checkpointer(str(tmp_path))
    cp.save(1, state)
    like = jax.eval_shape(lambda: TL.init_train_state(model, KEY))
    restored, _ = cp.restore(like, shardings=None)
    assert jax.tree.structure(restored) == jax.tree.structure(state)
