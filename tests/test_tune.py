"""repro.tune: bucketing, profile persistence, tuned-mode routing."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import plan as plan_mod
from repro.core.kernelgen import KernelSig
from repro.tune import classes, profile as profile_mod, search
from repro.tune.classes import SizeClass
from repro.tune.profile import DeviceProfile, ProfileEntry
from repro.tune.timer import Measurement, measure


@pytest.fixture(autouse=True)
def _isolated_profile_state(tmp_path, monkeypatch):
    """Each test gets an empty cache dir and no active profile."""
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    profile_mod.clear_active_profile()
    yield
    profile_mod.clear_active_profile()


# -- size classes ----------------------------------------------------------

def test_bucket_boundaries_exact():
    # powers of GROWTH=2 open a new bucket exactly at the power
    for i in range(1, 12):
        lo, hi = classes.bucket_bounds(i)
        assert lo == 2 ** i
        assert classes.bucket_index(2 ** i) == i
        assert classes.bucket_index(2 ** i - 1) == i - 1
        assert classes.bucket_index(2 ** (i + 1) - 1) == i
        assert lo <= classes.bucket_representative(i) < hi


def test_bucketing_deterministic_and_total():
    for x in list(range(1, 300)) + [1023, 1024, 1 << 20]:
        i = classes.bucket_index(x)
        lo, hi = classes.bucket_bounds(i)
        assert lo <= x < hi
        assert classes.bucket_index(x) == i   # idempotent / deterministic


def test_size_class_key_roundtrip():
    sc = classes.size_class(45, 129, 7, "S", "NT")
    assert SizeClass.from_key(sc.key) == sc
    M, N, K = classes.representative(sc)
    assert classes.size_class(M, N, K, "S", "NT") == sc


def test_bucket_rejects_nonpositive():
    with pytest.raises(ValueError):
        classes.bucket_index(0)


def test_classes_up_to_cube_diagonal():
    cs = classes.classes_up_to(["S"], ["NN"], 128, min_dim=8,
                               cube_only=True)
    # buckets whose representative (11, 23, 45, 91) lands in [8, 128]
    assert len(cs) == 4
    assert all(sc.mb == sc.nb == sc.kb for sc in cs)
    for sc in cs:
        assert all(8 <= d <= 128 for d in classes.representative(sc))
    full = classes.classes_up_to(["S"], ["NN"], 128, min_dim=8)
    assert len(full) == 4 ** 3


# -- profile persistence ---------------------------------------------------

def _entry(pallas_us, xla_us, sig=KernelSig("S", "NN", 64, 128, 128)):
    m = lambda us: Measurement(us, us * 0.9, us * 1.1, 3)  # noqa: E731
    return ProfileEntry(sig, m(pallas_us), m(xla_us))


def test_profile_save_load_roundtrip(tmp_path):
    prof = DeviceProfile("cpu")
    sc = classes.size_class(45, 45, 45, "S", "NN")
    prof.record(sc, _entry(10.0, 20.0))
    path = prof.save(tmp_path / "p.json")
    back = DeviceProfile.load(path)
    assert back.to_json() == prof.to_json()
    e = back.lookup(sc)
    assert e.prefer_pallas
    assert e.sig == KernelSig("S", "NN", 64, 128, 128)
    assert e.pallas.median_us == 10.0


def test_profile_default_path_uses_env_cache(tmp_path):
    p = profile_mod.default_profile_path("cpu")
    assert str(p).startswith(str(tmp_path / "cache"))
    assert "cpu" in p.name


def test_profile_version_gate(tmp_path):
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"version": 0, "device_kind": "cpu",
                                "entries": {}}))
    with pytest.raises(ValueError):
        DeviceProfile.load(path)


def test_profile_merge_keeps_better_entry():
    sc1 = classes.size_class(45, 45, 45, "S", "NN")
    sc2 = classes.size_class(90, 90, 90, "S", "NN")
    a = DeviceProfile("cpu")
    a.record(sc1, _entry(10.0, 20.0))
    b = DeviceProfile("cpu")
    b.record(sc1, _entry(5.0, 20.0))      # faster winner: should replace
    b.record(sc2, _entry(30.0, 8.0))      # new class: should union in
    merged = a.merge(b)
    assert len(merged) == 2
    assert merged.lookup(sc1).pallas.median_us == 5.0
    assert not merged.lookup(sc2).prefer_pallas


def test_profile_merge_rejects_device_mismatch():
    with pytest.raises(ValueError):
        DeviceProfile("cpu").merge(DeviceProfile("TPU_v5e"))


def test_profile_merge_rejects_mode_mismatch():
    with pytest.raises(ValueError):
        DeviceProfile("cpu", mode="interpret").merge(
            DeviceProfile("cpu", mode="compiled"))


def test_compiled_profile_preferred_over_interpret():
    sc = classes.size_class(45, 45, 45, "S", "NN")
    interp = DeviceProfile(profile_mod.current_device_kind(),
                           mode="interpret")
    interp.record(sc, _entry(100.0, 1.0))     # interpret says xla
    interp.save()
    compiled = DeviceProfile(profile_mod.current_device_kind(),
                             mode="compiled")
    compiled.record(sc, _entry(1.0, 100.0))   # compiled says pallas
    compiled.save()
    assert interp.save() != compiled.save()   # distinct per-mode files
    profile_mod.clear_active_profile()
    active = profile_mod.active_profile()
    assert active.mode == "compiled"
    assert active.lookup(sc).prefer_pallas


def test_unmeasured_entry_falls_back_analytical():
    sc = classes.size_class(45, 45, 45, "S", "NN")
    prof = DeviceProfile(profile_mod.current_device_kind())
    prof.record(sc, ProfileEntry(None, None, None))   # sweep all-failed
    profile_mod.set_active_profile(prof)
    d = api.route("gemm", (45, 45, 45), "S", "NN",
                  policy=api.Policy(backend="tuned"))
    assert d.source == "analytical"


# -- timer -----------------------------------------------------------------

def test_measure_median_of_k():
    m = measure(lambda: jnp.zeros((4, 4)), warmup=1, reps=3)
    assert m.reps == 3
    assert 0 < m.best_us <= m.median_us <= m.worst_us


# -- tuned-mode dispatch ---------------------------------------------------

def _gemm_operands(M, N, K, seed=0):
    rng = np.random.RandomState(seed)
    return (jnp.asarray(rng.randn(M, K), jnp.float32),
            jnp.asarray(rng.randn(K, N), jnp.float32))


def test_tuned_mode_falls_back_analytical_without_profile():
    assert profile_mod.active_profile() is None
    cfg = api.Policy(backend="tuned")
    d = api.route("gemm", (10, 10, 10), "S", "NN", policy=cfg)
    assert d.source == "analytical"
    auto = api.route("gemm", (10, 10, 10), "S", "NN",
                     policy=api.Policy(backend="auto"))
    assert d.use_pallas == auto.use_pallas
    a, b = _gemm_operands(10, 10, 10)
    with api.using(backend="tuned"):
        out = api.gemm(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b), rtol=2e-5)


def test_tuned_mode_reads_profile():
    """The acceptance check: a profile on disk provably changes routing."""
    M = N = K = 45
    sc = classes.size_class(M, N, K, "S", "NN")
    # analytical auto-mode would choose pallas for this small problem...
    assert api.route("gemm", (M, N, K), "S", "NN",
                     policy=api.Policy(backend="auto")).use_pallas
    # ...but the measured profile says XLA wins this class.
    prof = DeviceProfile(profile_mod.current_device_kind())
    prof.record(sc, _entry(100.0, 1.0))
    prof.save()                            # default (env-cache) path
    profile_mod.clear_active_profile()     # force the lazy disk load
    cfg = api.Policy(backend="tuned")
    d = api.route("gemm", (M, N, K), "S", "NN", policy=cfg)
    assert d.source == "profile"
    assert not d.use_pallas
    a, b = _gemm_operands(M, N, K)
    with api.using(backend="tuned"):
        out = api.gemm(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b), rtol=2e-5)


def test_tuned_mode_kernel_override_used():
    M = N = K = 45
    sc = classes.size_class(M, N, K, "S", "NN")
    sig = KernelSig("S", "NN", 32, 128, 256)
    prof = DeviceProfile(profile_mod.current_device_kind())
    prof.record(sc, _entry(1.0, 100.0, sig=sig))
    profile_mod.set_active_profile(prof)
    cfg = api.Policy(backend="tuned")
    d = api.route("gemm", (M, N, K), "S", "NN", policy=cfg)
    assert d.source == "profile" and d.use_pallas and d.sig == sig
    p = plan_mod.build_plan(M, N, K, "S", "NN", cfg.method, override=d.sig)
    assert p.num_kernel_calls == 1
    assert p.regions[0].sig == sig
    p.tiling.validate_cover()
    a, b = _gemm_operands(M, N, K)
    with api.using(backend="tuned"):
        out = api.gemm(a, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a) @ np.asarray(b),
                               rtol=2e-5, atol=2e-4)


def test_analytical_paths_unchanged_by_profile():
    """auto/pallas/xla backends never consult the profile."""
    prof = DeviceProfile(profile_mod.current_device_kind())
    sc = classes.size_class(10, 10, 10, "S", "NN")
    prof.record(sc, _entry(100.0, 1.0))    # profile says xla
    profile_mod.set_active_profile(prof)
    assert api.route("gemm", (10, 10, 10), "S", "NN",
                     policy=api.Policy(backend="auto")).use_pallas
    assert api.route(
        "gemm", (10, 10, 10), "S", "NN",
        policy=api.Policy(backend="pallas")).source == "forced"


def test_install_tune_writes_and_activates_profile():
    from repro.core import kernelgen
    n = kernelgen.install(["S"], ["NN"], interpret=True, max_per_family=1,
                          tune=True,
                          tune_kwargs=dict(min_dim=8, max_dim=16, reps=1,
                                           top=1))
    assert n == 1
    assert profile_mod.default_profile_path().exists()
    prof = profile_mod.active_profile()
    assert prof is not None and len(prof) == 1


# -- sweep + CLI -----------------------------------------------------------

def test_sweep_single_class_and_cli(tmp_path, capsys):
    prof = search.sweep(["S"], ["NN"], min_dim=8, max_dim=16,
                        cube_only=True, top=1, reps=1, interpret=True)
    assert len(prof) == 1
    (entry,) = prof.entries.values()
    assert entry.xla is not None or entry.pallas is not None

    from repro.tune.__main__ import main
    out = tmp_path / "cli.json"
    rc = main(["--letters", "S", "--trans", "NN", "--quick",
               "--min-dim", "8", "--max-dim", "16", "--reps", "1",
               "--out", str(out)])
    assert rc == 0
    assert out.exists()
    written = DeviceProfile.load(out)
    assert len(written) == 1
    rc = main(["--show", "--out", str(out)])
    assert rc == 0
    assert "entries" in capsys.readouterr().out
