"""repro.tune.online: traffic weighting from the windowed feed, budget
enforcement, merge provenance, live swap plumbing, the background
thread lifecycle, and Router thread-safety under profile-swap hammering."""
import threading
import time

import pytest

from repro import api, obs
from repro.api import Policy
from repro.core.kernelgen import KernelSig
from repro.tune import classes, online, profile as profile_mod, search
from repro.tune.classes import SizeClass
from repro.tune.online import OnlineTuner, weighted_targets
from repro.tune.profile import DeviceProfile, ProfileEntry
from repro.tune.search import TuneTarget
from repro.tune.timer import Measurement


@pytest.fixture(autouse=True)
def _isolated_state(tmp_path, monkeypatch):
    """Empty tune cache, no active profile, clean obs — before and after."""
    monkeypatch.setenv(profile_mod.CACHE_ENV, str(tmp_path / "cache"))
    obs.set_enabled(True)
    obs.reset()
    profile_mod.clear_active_profile()
    obs.TRACE.reset()
    yield
    profile_mod.clear_active_profile()
    obs.set_enabled(True)
    obs.reset()


def _m(us: float) -> Measurement:
    return Measurement(us, us, us, 1)


def _entry(pallas_us=None, xla_us=None, sig=None, origin="sweep"):
    return ProfileEntry(sig, _m(pallas_us) if pallas_us else None,
                        _m(xla_us) if xla_us else None, origin)


def _kind() -> str:
    return profile_mod.current_device_kind()


# -- traffic weighting ------------------------------------------------------

def test_weighted_targets_orders_by_traffic_and_merges_ops():
    folded = {("gemm", "S", "3-3-3"): 10.0,
              ("matmul", "S", "3-3-3"): 5.0,      # same class, same kind
              ("gemm", "S", "5-5-5"): 8.0}
    ts = weighted_targets(folded)
    assert [t.sc.key for t in ts] == ["S/NN/3-3-3", "S/NN/5-5-5"]
    assert ts[0].weight == 15.0 and ts[0].kind == "gemm"


def test_weighted_targets_ignores_cold_classes():
    folded = {("gemm", "S", "3-3-3"): 5.0, ("gemm", "S", "4-4-4"): 0.25}
    ts = weighted_targets(folded, min_weight=1.0)
    assert [t.sc.key for t in ts] == ["S/NN/3-3-3"]


def test_weighted_targets_grouped_ops_map_to_grouped_kind():
    folded = {("batched_gemm", "S", "2-4-4"): 3.0,
              ("ragged_gemm", "S", "2-4-4"): 1.0,
              ("gemm", "S", "2-4-4"): 2.0}
    ts = weighted_targets(folded)
    kinds = {t.kind: t.weight for t in ts}
    # grouped ops pool together but never merge with the 2-D kind: the
    # same class measures differently on the grouped kernel
    assert kinds == {"grouped": 4.0, "gemm": 2.0}


def test_weighted_targets_done_skip_until_traffic_shifts():
    folded = {("gemm", "S", "3-3-3"): 10.0}
    done = {("gemm", "S/NN/3-3-3"): 9.0}
    # 10 <= 1.5 * 9: steady traffic, already tuned -> skipped
    assert weighted_targets(folded, done=done, retune_ratio=1.5) == []
    # a real shift (weight > ratio * last-tuned weight) re-tunes
    folded[("gemm", "S", "3-3-3")] = 20.0
    ts = weighted_targets(folded, done=done, retune_ratio=1.5)
    assert len(ts) == 1 and ts[0].weight == 20.0


def test_weighted_targets_top_k_and_max_dim():
    folded = {("gemm", "S", f"{i}-{i}-{i}"): float(10 - i)
              for i in range(2, 9)}
    ts = weighted_targets(folded, top_k=3)
    assert len(ts) == 3
    assert ts[0].weight > ts[1].weight > ts[2].weight
    # bucket 8's representative (362) exceeds max_dim=64 -> the valve
    # drops it no matter how hot
    folded[("gemm", "S", "8-8-8")] = 1000.0
    ts = weighted_targets(folded, max_dim=64)
    assert all(t.sc.key != "S/NN/8-8-8" for t in ts)


def test_windowed_decay_feeds_priorities():
    """Recent traffic outranks heavier-but-older traffic: the decayed
    windowed fold is what the weighter consumes, not the raw totals."""
    r = api.Router(Policy(backend="auto"))
    for _ in range(3):
        r.route("gemm", (45, 45, 45), "S", "NN")      # class 5-5-5
    obs.ROUTES.windowed(now=0.0)                      # init window clock
    obs.ROUTES.windowed(now=1.5)                      # close bucket: A x3
    for _ in range(2):
        r.route("gemm", (300, 300, 300), "S", "NN")   # class 8-8-8, fresh
    folded = obs.ROUTES.windowed(8, decay=0.5, now=1.6)
    b = classes.bucket_index
    ka = ("gemm", "S", f"{b(45)}-{b(45)}-{b(45)}")
    kb = ("gemm", "S", f"{b(300)}-{b(300)}-{b(300)}")
    assert folded[ka] == pytest.approx(1.5)           # 3 decayed once
    assert folded[kb] == pytest.approx(2.0)           # open bucket
    ts = weighted_targets(folded)
    assert ts[0].sc.key == "S/NN/8-8-8"               # recency wins


# -- budget enforcement -----------------------------------------------------

def test_budgeted_sweep_enforces_timing_budget(monkeypatch):
    calls = [0]

    def fake_measure(fn, *, warmup, reps):
        calls[0] += 1
        return _m(1.0)

    monkeypatch.setattr(search, "try_measure", fake_measure)
    targets = [TuneTarget("gemm", SizeClass("S", "NN", i, i, i), 10.0 - i)
               for i in range(2, 7)]
    prof, tuned, spent = search.budgeted_sweep(targets, budget=4, top=1)
    # each class costs 1 (xla) + 1 (top candidate) = 2 timings: budget 4
    # covers exactly the two hottest classes, and the sweep stops BEFORE
    # starting a class it cannot finish
    assert len(tuned) == 2 and spent == 4 and calls[0] <= 4
    assert [t.sc.key for t in tuned] == ["S/NN/2-2-2", "S/NN/3-3-3"]
    assert len(prof) == 2


def test_budgeted_sweep_records_grouped_namespace_and_origin(monkeypatch):
    monkeypatch.setattr(search, "try_measure",
                        lambda fn, *, warmup, reps: _m(1.0))
    sc = SizeClass("S", "NN", 2, 4, 4)
    prof, tuned, _ = search.budgeted_sweep(
        [TuneTarget("grouped", sc, 5.0)], budget=8, top=1)
    assert prof.lookup(sc) is None                    # not in the 2-D space
    e = prof.lookup_grouped(sc)
    assert e is not None and e.measured and e.origin == "online"
    # the namespace survives a JSON roundtrip untouched
    back = DeviceProfile.from_json(prof.to_json())
    assert back.lookup_grouped(sc) is not None


# -- merge provenance -------------------------------------------------------

def test_merge_newer_entry_wins_only_when_better():
    sc = SizeClass("S", "NN", 3, 3, 3)
    sig = KernelSig("S", "NN", 128, 128, 128)
    base = DeviceProfile(_kind())
    base.record(sc, _entry(5.0, 50.0, sig=sig, origin="sweep"))
    worse = DeviceProfile(_kind())
    worse.record(sc, _entry(10.0, 50.0, sig=sig, origin="online"))
    merged = base.merge(worse)
    assert merged.lookup(sc).origin == "sweep"        # old entry kept
    better = DeviceProfile(_kind())
    better.record(sc, _entry(2.0, 50.0, sig=sig, origin="online"))
    merged = base.merge(better)
    assert merged.lookup(sc).origin == "online"       # displaced: faster
    assert merged.lookup(sc).pallas.median_us == 2.0


def test_profile_entry_origin_json_default_is_sweep():
    e = _entry(3.0, 4.0, sig=KernelSig("S", "NN", 16, 128, 128),
               origin="online")
    assert ProfileEntry.from_json(e.to_json()).origin == "online"
    legacy = e.to_json()
    del legacy["origin"]                              # pre-online profile
    assert ProfileEntry.from_json(legacy).origin == "sweep"


# -- the cycle --------------------------------------------------------------

def _route_traffic(n=3):
    r = api.Router(Policy(backend="auto"))
    for _ in range(n):
        r.route("gemm", (45, 45, 45), "S", "NN")
        r.route("batched_gemm", (4, 8, 16, 24), "S", "NN")


def _stub_sweeper(pallas_us=1.0, xla_us=2.0):
    """A sweeper double honoring the budgeted_sweep contract."""
    def sweeper(targets, *, budget):
        prof = DeviceProfile(_kind())
        tuned, spent = [], 0
        for t in targets:
            if spent + 2 > budget:
                break
            e = _entry(pallas_us, xla_us,
                       sig=KernelSig("S", "NN", 128, 128, 128),
                       origin="online")
            (prof.record_grouped if t.kind == "grouped"
             else prof.record)(t.sc, e)
            tuned.append(t)
            spent += 2
        return prof, tuned, spent
    return sweeper


def test_cycle_retunes_merges_and_swaps():
    _route_traffic()
    tn = OnlineTuner(sweeper=_stub_sweeper(), budget=8)
    gen0 = obs.ROUTES.gen
    rep = tn.cycle()
    assert rep.cycle == 1 and rep.considered == 2 and rep.retuned == 2
    assert rep.timings == 4 and rep.swapped
    # the swap went live: profile installed, memo invalidated, traced
    prof = profile_mod.active_profile()
    assert prof is not None and len(prof) == 2
    assert obs.ROUTES.gen > gen0
    types = [e[1] for e in obs.TRACE.snapshot()]
    assert "TUNE_CYCLE" in types and "PROFILE_SWAP" in types
    cyc = [e for e in obs.TRACE.snapshot() if e[1] == "TUNE_CYCLE"][-1]
    assert cyc[4] == (1, 2, 4, True) and cyc[5] and cyc[5] > 0
    assert obs.counter("tune.online.cycles").value == 1
    assert obs.counter("tune.online.classes_retuned").value == 2
    assert obs.counter("tune.online.swaps").value == 1
    assert obs.REGISTRY.get("tune.online.cycle_us").count == 1
    # tuned-mode dispatch now routes by the swapped-in entries
    d = api.route("gemm", (45, 45, 45), "S", "NN",
                  policy=Policy(backend="tuned"))
    assert d.source == "profile" and d.use_pallas
    d = api.route("batched_gemm", (4, 8, 16, 24), "S", "NN",
                  policy=Policy(backend="tuned"))
    assert d.source == "profile" and d.blocks == (128, 128, 128)


def test_cycle_without_traffic_is_a_quiet_noop():
    tn = OnlineTuner(sweeper=_stub_sweeper())
    rep = tn.cycle()
    assert rep.retuned == 0 and not rep.swapped
    # trace first: the active_profile() read below lazily loads and
    # emits its own PROFILE_SWAP, which is not the tuner's doing
    types = [e[1] for e in obs.TRACE.snapshot()]
    assert "PROFILE_SWAP" not in types and "TUNE_CYCLE" in types
    assert profile_mod.active_profile() is None


def test_cycle_steady_traffic_tunes_once():
    _route_traffic()
    tn = OnlineTuner(sweeper=_stub_sweeper(), budget=8)
    assert tn.cycle().retuned == 2
    # same traffic, no shift: the done-tracker skips both classes
    rep2 = tn.cycle()
    assert rep2.retuned == 0 and not rep2.swapped


def test_cycle_mode_mismatch_skips_merge():
    _route_traffic()
    live = DeviceProfile(_kind(), mode="compiled")
    live.record(SizeClass("S", "NN", 1, 1, 1),
                _entry(1.0, 2.0, sig=KernelSig("S", "NN", 16, 128, 128)))
    profile_mod.set_active_profile(live)
    tn = OnlineTuner(sweeper=_stub_sweeper(), budget=8)   # interpret mode
    rep = tn.cycle()
    assert rep.retuned == 2 and not rep.swapped
    assert profile_mod.active_profile() is live           # untouched
    assert obs.counter("tune.online.merge_skips").value == 1


# -- kill switch + background lifecycle -------------------------------------

def test_kill_switch_disables_start(monkeypatch):
    monkeypatch.setenv(online.KILL_SWITCH_ENV, "0")
    assert not online.enabled()
    tn = OnlineTuner(sweeper=_stub_sweeper())
    assert tn.start() is False and not tn.running
    assert tn.stop()                                  # no-op, still clean
    monkeypatch.delenv(online.KILL_SWITCH_ENV)
    assert online.enabled()


def test_background_thread_cycles_and_stops_clean():
    _route_traffic()
    tn = OnlineTuner(sweeper=_stub_sweeper(), interval_s=0.01, budget=8)
    assert tn.start() and tn.running
    assert tn.start()                                 # idempotent
    deadline = time.time() + 5.0
    while tn.cycles < 2 and time.time() < deadline:
        time.sleep(0.01)
    assert tn.cycles >= 2
    assert tn.stop() and not tn.running
    n = tn.cycles
    time.sleep(0.05)
    assert tn.cycles == n                             # really stopped
    # restartable after stop
    assert tn.start() and tn.running
    assert tn.stop()


def test_context_manager_runs_and_joins():
    _route_traffic()
    with OnlineTuner(sweeper=_stub_sweeper(), interval_s=0.01) as tn:
        deadline = time.time() + 5.0
        while tn.cycles < 1 and time.time() < deadline:
            time.sleep(0.01)
    assert not tn.running and tn.cycles >= 1


# -- router consumes grouped entries ----------------------------------------

def test_router_prefers_grouped_entry_over_2d_reuse():
    sc = classes.size_class(8, 24, 16, "S", "NN")     # (C, N, K)
    prof = DeviceProfile(_kind())
    # the 2-D timing says XLA; the grouped-kernel timing says pallas
    # with its own blocks — the grouped entry must win
    prof.record(sc, _entry(100.0, 1.0,
                           sig=KernelSig("S", "NN", 16, 128, 128)))
    prof.record_grouped(sc, _entry(1.0, 100.0,
                                   sig=KernelSig("S", "NN", 8, 128, 256),
                                   origin="online"))
    profile_mod.set_active_profile(prof)
    d = api.route("batched_gemm", (4, 8, 16, 24), "S", "NN",
                  policy=Policy(backend="tuned"))
    assert d.source == "profile" and d.use_pallas
    assert d.blocks == (8, 128, 256)


def test_router_falls_back_to_2d_entry_without_grouped_one():
    sc = classes.size_class(8, 24, 16, "S", "NN")
    prof = DeviceProfile(_kind())
    prof.record(sc, _entry(1.0, 100.0,
                           sig=KernelSig("S", "NN", 16, 128, 128)))
    profile_mod.set_active_profile(prof)
    d = api.route("batched_gemm", (4, 8, 16, 24), "S", "NN",
                  policy=Policy(backend="tuned"))
    assert d.source == "profile" and d.use_pallas
    assert d.blocks == (16, 128, 128)                 # legacy 2-D reuse


# -- thread safety: route readers vs profile-swap hammering ------------------

def test_router_route_readers_survive_profile_swap_hammer():
    """Mirror of the PR-9 RouteLog.note stress test, pointed at the
    swap path: reader threads routing under backend="tuned" (active-
    profile lookups + memo hits/misses) race a thread hammering
    ``set_active_profile`` (locked global swap + gen bump + trace emit).
    No exceptions, and every decision is internally consistent."""
    sc = classes.size_class(45, 45, 45, "S", "NN")
    profs = []
    for pallas_us, xla_us in ((1.0, 9.0), (9.0, 1.0)):
        p = DeviceProfile(_kind())
        p.record(sc, _entry(pallas_us, xla_us,
                            sig=KernelSig("S", "NN", 128, 128, 128)))
        profs.append(p)
    errors, stop = [], threading.Event()

    def hammer():
        i = 0
        try:
            while not stop.is_set():
                profile_mod.set_active_profile(profs[i % 2])
                i += 1
        except Exception as e:                        # pragma: no cover
            errors.append(e)

    def read(tid):
        try:
            r = api.Router(Policy(backend="tuned"))
            for i in range(300):
                m = 8 + ((tid * 300 + i) % 61)
                d = r.route("gemm", (m, m, m), "S", "NN")
                assert d.source in ("profile", "analytical")
                d45 = r.route("gemm", (45, 45, 45), "S", "NN")
                # whichever profile was live, the decision came from it
                assert d45.source == "profile"
        except Exception as e:                        # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=read, args=(t,)) for t in range(4)]
    hammerer = threading.Thread(target=hammer)
    hammerer.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    hammerer.join()
    assert not errors


# -- the real measuring harness (one tiny class; everything above stubs) ----

def test_tune_grouped_class_measures_real_kernels():
    sc = classes.size_class(8, 8, 8, "S", "NN")       # representative 11^3
    e = search.tune_grouped_class(sc, G=2, top=1, warmup=0, reps=1)
    assert e.measured and e.xla is not None
    if e.sig is not None:                             # a candidate ran
        assert e.pallas is not None and e.pallas.median_us > 0
